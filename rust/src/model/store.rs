//! Shard-owned parameter store: weights, Adam moments, lazy-Adam row
//! state and maintained per-field norms, partitioned for a parallel
//! apply stage.
//!
//! The PR-2 trainer kept one leader-owned `ParamSet` plus two dense
//! moment `ParamSet`s, which forced CowClip's `clip → L2 → Adam → apply`
//! to run serially over the full table — exactly the embedding-heavy
//! stage the paper says dominates CTR training. [`ParamStore`] inverts
//! that ownership:
//!
//! * **Vocab-shaped tables** (`embed`/`wide` groups) are partitioned
//!   row-wise into shards whose boundaries are **field-aligned**, so
//!   every clipping mode stays shard-local (`Global` gets its whole-table
//!   gradient norm precomputed once). Each shard owns its rows' weights,
//!   Adam moments and lazy-Adam last-touch steps for the duration of an
//!   apply.
//! * **Dense parameters** are grouped onto shards greedily by scalar
//!   count, so the MLP/cross tensors spread across the same owners.
//! * **Per-field `Σw²`** is maintained incrementally as rows change
//!   (subtract the old row's mass, add the new), making sparse AdaField's
//!   adaptive threshold an O(1) read per field instead of the O(V · d)
//!   table scan the ablation mode used to pay every step.
//!
//! Shard execution is embarrassingly parallel — every work item holds
//! disjoint `&mut` slices carved with `split_at_mut` — so the result is
//! bitwise identical at any shard/thread count (`rust/tests/
//! shard_parity.rs` pins this against the legacy serial oracle).
//!
//! Weights live behind a `RwLock` and optimizer state behind a `Mutex`:
//! the persistent step-worker pool reads parameters concurrently during
//! the gradient fan-out, and the apply stage takes the write side — no
//! per-step thread spawn, no copies.
//!
//! # Checkpoints
//!
//! [`ParamStore::save_checkpoint`] writes a `CCKS` file: a small header
//! (version + optimizer step), the params / m / v as three PR-1 `CCKP`
//! blocks, then the per-row lazy-Adam step tables. The layout is
//! canonical (dense, shard-count independent), so any `--param-shards`
//! value loads any checkpoint, and [`ParamStore::load_checkpoint`] also
//! accepts a bare `CCKP` params file (moments reset, step 0).

use std::borrow::Cow;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::{Mutex, RwLock, RwLockReadGuard};

use anyhow::{bail, ensure, Context, Result};

use super::manifest::ParamEntry;
use super::params::{ParamSet, CKPT_MAGIC};
use crate::clip::{clip_embedding_grads_range, grad_l2_norm, ClipMode, ClipParams};
use crate::data::schema::Schema;
use crate::optim::{lazy_step_rows, Adam, AdamConfig};
use crate::tensor::{merge_row_slices, GradTensor, SparseRows, Tensor};
use crate::wire::codec::{read_u32_le, read_u32_vec, read_u64_le, write_u32_le, write_u64_le};

const STORE_MAGIC: &[u8; 4] = b"CCKS";
const STORE_VERSION: u32 = 1;

/// How parameters are split across apply-stage shard owners.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    n_shards: usize,
    /// The schema's `(global_offset, vocab)` per categorical field,
    /// collected once so the per-step apply never re-walks the schema.
    fields: Vec<(usize, usize)>,
    /// Ascending field cuts (len `n_shards + 1`): shard `s` owns fields
    /// `[cuts[s], cuts[s+1])` of every vocab-shaped table.
    field_cuts: Vec<usize>,
    /// Global row ranges per shard, contiguous and covering `[0, V)`.
    row_ranges: Vec<(usize, usize)>,
    /// Per param: row-split vocab table or whole-tensor owner.
    assignments: Vec<Assignment>,
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum Assignment {
    /// Vocab-shaped table (`embed`/`wide`): rows split by `row_ranges`.
    Rows,
    /// Dense parameter: owned whole by one shard.
    Whole(usize),
}

impl ShardPlan {
    /// Build a plan: field-aligned row cuts balanced by vocab mass, dense
    /// tensors spread greedily by scalar count. Deterministic.
    pub fn build(spec: &[ParamEntry], schema: &Schema, n_shards: usize) -> Result<ShardPlan> {
        ensure!(n_shards >= 1, "shard count must be >= 1");
        let fields: Vec<(usize, usize)> = schema.fields().collect();
        let total = schema.total_vocab();
        let cuts = field_cuts(&fields, n_shards);
        let row_ranges: Vec<(usize, usize)> = (0..n_shards)
            .map(|s| (row_of(&fields, cuts[s], total), row_of(&fields, cuts[s + 1], total)))
            .collect();
        let mut dense_load = vec![0usize; n_shards];
        let mut assignments = Vec::with_capacity(spec.len());
        for e in spec {
            if matches!(e.group.as_str(), "embed" | "wide") {
                ensure!(
                    e.shape[0] == total,
                    "vocab table {} has {} rows but the schema vocab is {total}",
                    e.name,
                    e.shape[0]
                );
                assignments.push(Assignment::Rows);
            } else {
                let s = (0..n_shards).min_by_key(|&s| (dense_load[s], s)).unwrap();
                dense_load[s] += e.numel();
                assignments.push(Assignment::Whole(s));
            }
        }
        Ok(ShardPlan { n_shards, fields, field_cuts: cuts, row_ranges, assignments })
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Global row ranges per shard (field-aligned, contiguous, covering).
    pub fn row_ranges(&self) -> &[(usize, usize)] {
        &self.row_ranges
    }

    /// Field-index span `[lo, hi)` owned by shard `s`.
    pub fn field_span(&self, s: usize) -> (usize, usize) {
        (self.field_cuts[s], self.field_cuts[s + 1])
    }
}

/// Proportional field cuts: shard `s` stops once the cumulative vocab
/// reaches `total * (s + 1) / n` (rounded up). Shards can be empty when
/// `n` exceeds the field count or one field dominates the vocab.
fn field_cuts(fields: &[(usize, usize)], n: usize) -> Vec<usize> {
    let total: usize = fields.iter().map(|&(_, v)| v).sum();
    let mut cuts = Vec::with_capacity(n + 1);
    cuts.push(0);
    let mut f = 0usize;
    let mut acc = 0usize;
    for s in 1..n {
        let target = (total * s).div_ceil(n);
        while f < fields.len() && acc < target {
            acc += fields[f].1;
            f += 1;
        }
        cuts.push(f);
    }
    cuts.push(fields.len());
    cuts
}

fn row_of(fields: &[(usize, usize)], cut: usize, total: usize) -> usize {
    if cut < fields.len() {
        fields[cut].0
    } else {
        total
    }
}

/// Everything the apply stage needs besides the gradients: resolved
/// hyperparameters (warmup already folded into `lr_dense`), the clip
/// mode, Adam constants, and the 1-based optimizer step.
#[derive(Clone, Copy, Debug)]
pub struct ApplyCtx {
    pub clip: ClipMode,
    pub clip_params: ClipParams,
    pub lr_embed: f32,
    pub lr_dense: f32,
    pub l2_embed: f32,
    pub adam: AdamConfig,
    /// 1-based optimizer step.
    pub step: u32,
}

/// Mutable optimizer state, locked as one unit during apply.
struct OptState {
    m: ParamSet,
    v: ParamSet,
    /// Per-row 1-based last-update step of each vocab table (lazy Adam);
    /// `None` for dense parameters.
    last_step: Vec<Option<Vec<u32>>>,
    /// Maintained per-field `Σw²` (f64) of each `embed`-group table;
    /// `None` elsewhere. AdaField reads `sqrt` of these.
    field_sqnorms: Vec<Option<Vec<f64>>>,
}

/// The shard-owned parameter store (see module docs).
pub struct ParamStore {
    spec: Vec<ParamEntry>,
    schema: Schema,
    plan: ShardPlan,
    weights: RwLock<ParamSet>,
    opt: Mutex<OptState>,
}

impl ParamStore {
    /// Wrap freshly initialized parameters; moments start at zero and the
    /// per-field norms are computed once from the initial weights.
    pub fn new(schema: Schema, params: ParamSet, n_shards: usize) -> Result<ParamStore> {
        let spec = params.spec.clone();
        let plan = ShardPlan::build(&spec, &schema, n_shards)?;
        let m = params.zeros_like();
        let v = params.zeros_like();
        let last_step = spec
            .iter()
            .map(|e| match e.group.as_str() {
                "embed" | "wide" => Some(vec![0u32; e.shape[0]]),
                _ => None,
            })
            .collect();
        let field_sqnorms = init_sqnorms(&spec, &schema, &params)?;
        Ok(ParamStore {
            spec,
            schema,
            plan,
            weights: RwLock::new(params),
            opt: Mutex::new(OptState { m, v, last_step, field_sqnorms }),
        })
    }

    pub fn spec(&self) -> &[ParamEntry] {
        &self.spec
    }

    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    pub fn n_shards(&self) -> usize {
        self.plan.n_shards
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Shared read access to the weights (gradient fan-out, eval, tests).
    pub fn read(&self) -> RwLockReadGuard<'_, ParamSet> {
        self.weights.read().unwrap()
    }

    /// The weight lock itself — captured by the persistent step-worker
    /// pool so workers can read parameters without borrowing the store.
    pub fn weights_lock(&self) -> &RwLock<ParamSet> {
        &self.weights
    }

    /// Owned copy of the current weights.
    pub fn snapshot(&self) -> ParamSet {
        self.read().clone()
    }

    /// Owned copies of the Adam moments `(m, v)`.
    pub fn moments(&self) -> (ParamSet, ParamSet) {
        let opt = self.opt.lock().unwrap();
        (opt.m.clone(), opt.v.clone())
    }

    /// Exclusive access to (params, m, v) as whole sets — the HLO apply
    /// program rewrites all three wholesale. The maintained field norms
    /// are *not* refreshed here (the HLO path never reads them; a
    /// checkpoint load recomputes them from the stored weights).
    pub fn with_all_mut<T>(
        &self,
        f: impl FnOnce(&mut ParamSet, &mut ParamSet, &mut ParamSet) -> Result<T>,
    ) -> Result<T> {
        let mut w = self.weights.write().unwrap();
        let mut opt = self.opt.lock().unwrap();
        let OptState { m, v, .. } = &mut *opt;
        f(&mut w, m, v)
    }

    /// CowClip's `clip → L2 → Adam → apply`, executed per parameter
    /// shard. With `threads > 1` (and more than one shard) the shards run
    /// on scoped threads; every work item owns disjoint `&mut` slices, so
    /// the result is bitwise identical at any shard/thread count.
    ///
    /// Vocab-table gradients normally arrive row-sparse; a dense gradient
    /// (the diagnostic `dense_grads` mode) is converted to an all-rows
    /// sparse payload first — lazy Adam over every row reproduces the
    /// eager update exactly, so one sharded code path serves both.
    pub fn apply_sharded(
        &self,
        ctx: &ApplyCtx,
        grads: &mut [GradTensor],
        counts: &SparseRows,
        threads: usize,
    ) -> Result<()> {
        ensure!(
            grads.len() == self.spec.len(),
            "grad arity {} != spec {}",
            grads.len(),
            self.spec.len()
        );
        let mut w_guard = self.weights.write().unwrap();
        let mut opt_guard = self.opt.lock().unwrap();
        let params: &mut ParamSet = &mut w_guard;
        let OptState { m, v, last_step, field_sqnorms } = &mut *opt_guard;

        // 0. densified vocab-table grads -> all-rows sparse (see above)
        for (e, g) in self.spec.iter().zip(grads.iter_mut()) {
            if !matches!(e.group.as_str(), "embed" | "wide")
                || matches!(g, GradTensor::Sparse(_))
            {
                continue;
            }
            let rows = e.shape[0];
            let d = e.numel() / rows;
            let taken = std::mem::replace(g, GradTensor::Sparse(SparseRows::empty(rows, d)));
            let GradTensor::Dense(t) = taken else { unreachable!("checked above") };
            debug_assert_eq!(t.len(), rows * d, "dense grad shape for {}", e.name);
            let vals = match t {
                Tensor::F32 { data, .. } => data,
                Tensor::I32 { .. } => bail!("non-f32 gradient for {}", e.name),
            };
            let ids: Vec<u32> = (0..rows as u32).collect();
            *g = GradTensor::Sparse(SparseRows::new(rows, d, ids, vals));
        }

        // 1. Global clip rescales by the *whole-table* gradient norm:
        // compute it once, before the rows are split across shards.
        let mut global_norms: Vec<Option<f32>> = vec![None; self.spec.len()];
        if ctx.clip == ClipMode::Global {
            for ((e, g), slot) in self.spec.iter().zip(grads.iter()).zip(global_norms.iter_mut())
            {
                if e.group == "embed" {
                    if let GradTensor::Sparse(s) = g {
                        *slot = Some(grad_l2_norm(s.vals()));
                    }
                }
            }
        }

        // 2. carve per-shard work items out of disjoint &mut slices
        let n_shards = self.plan.n_shards;
        let fields_all: &[(usize, usize)] = &self.plan.fields;
        let mut work: Vec<Vec<WorkItem<'_>>> = (0..n_shards).map(|_| Vec::new()).collect();
        let iter = self
            .spec
            .iter()
            .zip(self.plan.assignments.iter())
            .zip(params.tensors.iter_mut())
            .zip(m.tensors.iter_mut())
            .zip(v.tensors.iter_mut())
            .zip(grads.iter_mut())
            .zip(last_step.iter_mut())
            .zip(field_sqnorms.iter_mut())
            .zip(global_norms.iter());
        for ((((((((entry, assign), w_t), m_t), v_t), g), last), sq), gnorm) in iter {
            match assign {
                Assignment::Whole(s) => {
                    let GradTensor::Dense(g_t) = g else {
                        bail!("sparse gradient for dense-group param {}", entry.name)
                    };
                    work[*s].push(WorkItem::DenseTensor {
                        w: w_t.as_f32_mut()?,
                        m: m_t.as_f32_mut()?,
                        v: v_t.as_f32_mut()?,
                        g: g_t.as_f32_mut()?,
                        lr: ctx.lr_dense,
                    });
                }
                Assignment::Rows => {
                    let GradTensor::Sparse(sg) = g else {
                        bail!("dense gradient survived normalization for {}", entry.name)
                    };
                    let rows = entry.shape[0];
                    let d = sg.d();
                    ensure!(sg.n_rows() == rows, "grad rows mismatch for {}", entry.name);
                    let is_embed = entry.group == "embed";
                    let ranges = &self.plan.row_ranges;
                    let w_parts = split_rows(w_t.as_f32_mut()?, d, ranges);
                    let m_parts = split_rows(m_t.as_f32_mut()?, d, ranges);
                    let v_parts = split_rows(v_t.as_f32_mut()?, d, ranges);
                    let last_parts =
                        split_rows(last.as_mut().expect("vocab table has lazy state"), 1, ranges);
                    let sq_parts: Vec<Option<&mut [f64]>> = match (is_embed, sq) {
                        (true, Some(sq)) => {
                            split_by_cuts(sq, &self.plan.field_cuts).into_iter().map(Some).collect()
                        }
                        _ => (0..n_shards).map(|_| None).collect(),
                    };
                    let g_parts = sg.range_views_mut(ranges);
                    for (s, (((((gv, wp), mp), vp), lp), sqp)) in g_parts
                        .into_iter()
                        .zip(w_parts)
                        .zip(m_parts)
                        .zip(v_parts)
                        .zip(last_parts)
                        .zip(sq_parts)
                        .enumerate()
                    {
                        let (flo, fhi) = self.plan.field_span(s);
                        let fields: &[(usize, usize)] =
                            if is_embed { &fields_all[flo..fhi] } else { &[] };
                        let hi = gv.base + gv.rows;
                        work[s].push(WorkItem::VocabTable {
                            base: gv.base,
                            d,
                            grad: TableGrad::Ready { ids: gv.ids, vals: gv.vals, counts, hi },
                            w: wp,
                            m: mp,
                            v: vp,
                            last: lp,
                            fields,
                            sqnorms: sqp,
                            clip: is_embed,
                            global_norm: *gnorm,
                            lr: ctx.lr_embed,
                        });
                    }
                }
            }
        }

        run_shards(work, ctx, threads)
    }

    /// [`ParamStore::apply_sharded`] for a reduction that arrived as the
    /// root's two subtree halves ([`crate::coordinator::Reduced::Halves`]):
    /// the final — largest — merge of the gradient tree is **split per
    /// shard row range and executed inside each shard's apply task**, so
    /// a shard starts clipping/stepping its range as soon as its slice
    /// of the merge completes while other shards' merge tail is still
    /// draining. Row-local union merging makes this bitwise identical to
    /// merging the whole tables first (gated by `shard_parity.rs` /
    /// `parallel_parity.rs`).
    ///
    /// Falls back to the eager whole-merge path when a vocab gradient is
    /// dense (the diagnostic `dense_grads` mode) or the clip mode is
    /// `Global` (whose threshold needs the *whole-table* merged norm
    /// before any shard may start).
    pub fn apply_sharded_pair(
        &self,
        ctx: &ApplyCtx,
        left: &mut [GradTensor],
        right: Vec<GradTensor>,
        left_counts: &SparseRows,
        right_counts: &SparseRows,
        threads: usize,
    ) -> Result<()> {
        ensure!(
            left.len() == self.spec.len() && right.len() == self.spec.len(),
            "grad arity {}/{} != spec {}",
            left.len(),
            right.len(),
            self.spec.len()
        );
        let splittable = ctx.clip != ClipMode::Global
            && self
                .spec
                .iter()
                .zip(left.iter())
                .zip(right.iter())
                .all(|((e, l), r)| {
                    !matches!(e.group.as_str(), "embed" | "wide")
                        || (matches!(l, GradTensor::Sparse(_))
                            && matches!(r, GradTensor::Sparse(_)))
                });
        if !splittable {
            // eager fallback: merge the halves, then the normal path
            for (l, r) in left.iter_mut().zip(&right) {
                l.axpy(1.0, r)?;
            }
            let mut counts = left_counts.clone();
            counts.axpy(1.0, right_counts)?;
            return self.apply_sharded(ctx, left, &counts, threads);
        }

        let mut w_guard = self.weights.write().unwrap();
        let mut opt_guard = self.opt.lock().unwrap();
        let params: &mut ParamSet = &mut w_guard;
        let OptState { m, v, last_step, field_sqnorms } = &mut *opt_guard;

        let n_shards = self.plan.n_shards;
        let fields_all: &[(usize, usize)] = &self.plan.fields;
        let ranges = &self.plan.row_ranges;
        let mut work: Vec<Vec<WorkItem<'_>>> = (0..n_shards).map(|_| Vec::new()).collect();
        let iter = self
            .spec
            .iter()
            .zip(self.plan.assignments.iter())
            .zip(params.tensors.iter_mut())
            .zip(m.tensors.iter_mut())
            .zip(v.tensors.iter_mut())
            .zip(left.iter_mut())
            .zip(right.iter())
            .zip(last_step.iter_mut())
            .zip(field_sqnorms.iter_mut());
        for ((((((((entry, assign), w_t), m_t), v_t), lg), rg), last), sq) in iter {
            match assign {
                Assignment::Whole(s) => {
                    // dense params are small: merge on the leader, then
                    // hand the shard the merged tensor as usual
                    lg.axpy(1.0, rg)?;
                    let GradTensor::Dense(g_t) = lg else {
                        bail!("sparse gradient for dense-group param {}", entry.name)
                    };
                    work[*s].push(WorkItem::DenseTensor {
                        w: w_t.as_f32_mut()?,
                        m: m_t.as_f32_mut()?,
                        v: v_t.as_f32_mut()?,
                        g: g_t.as_f32_mut()?,
                        lr: ctx.lr_dense,
                    });
                }
                Assignment::Rows => {
                    let (GradTensor::Sparse(ls), GradTensor::Sparse(rs)) = (&*lg, rg) else {
                        bail!("dense vocab gradient on the split path for {}", entry.name)
                    };
                    let rows = entry.shape[0];
                    let d = ls.d();
                    ensure!(
                        ls.n_rows() == rows && rs.n_rows() == rows && rs.d() == d,
                        "grad rows mismatch for {}",
                        entry.name
                    );
                    let is_embed = entry.group == "embed";
                    let w_parts = split_rows(w_t.as_f32_mut()?, d, ranges);
                    let m_parts = split_rows(m_t.as_f32_mut()?, d, ranges);
                    let v_parts = split_rows(v_t.as_f32_mut()?, d, ranges);
                    let last_parts =
                        split_rows(last.as_mut().expect("vocab table has lazy state"), 1, ranges);
                    let sq_parts: Vec<Option<&mut [f64]>> = match (is_embed, sq) {
                        (true, Some(sq)) => {
                            split_by_cuts(sq, &self.plan.field_cuts).into_iter().map(Some).collect()
                        }
                        _ => (0..n_shards).map(|_| None).collect(),
                    };
                    for (s, ((((wp, mp), vp), lp), sqp)) in w_parts
                        .into_iter()
                        .zip(m_parts)
                        .zip(v_parts)
                        .zip(last_parts)
                        .zip(sq_parts)
                        .enumerate()
                    {
                        let (lo, hi) = ranges[s];
                        let (flo, fhi) = self.plan.field_span(s);
                        let fields: &[(usize, usize)] =
                            if is_embed { &fields_all[flo..fhi] } else { &[] };
                        let (l_ids, l_vals) = ls.range_slice(lo, hi);
                        let (r_ids, r_vals) = rs.range_slice(lo, hi);
                        let (lc, rc) = if is_embed {
                            (left_counts.range_slice(lo, hi), right_counts.range_slice(lo, hi))
                        } else {
                            ((&[][..], &[][..]), (&[][..], &[][..]))
                        };
                        work[s].push(WorkItem::VocabTable {
                            base: lo,
                            d,
                            grad: TableGrad::Merge { l_ids, l_vals, r_ids, r_vals, lc, rc },
                            w: wp,
                            m: mp,
                            v: vp,
                            last: lp,
                            fields,
                            sqnorms: sqp,
                            clip: is_embed,
                            global_norm: None,
                            lr: ctx.lr_embed,
                        });
                    }
                }
            }
        }
        run_shards(work, ctx, threads)
    }

    /// Write the full training checkpoint (see module docs for layout).
    ///
    /// The file is written to a `.tmp` sibling and renamed into place, so
    /// a crash mid-save never destroys an existing checkpoint at `path`.
    pub fn save_checkpoint(&self, path: &Path, step: u64) -> Result<()> {
        let w_guard = self.read();
        let opt = self.opt.lock().unwrap();
        let tmp = path.with_extension("tmp");
        {
            let f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            let mut w = BufWriter::new(f);
            w.write_all(STORE_MAGIC)?;
            write_u32_le(&mut w, STORE_VERSION)?;
            write_u64_le(&mut w, step)?;
            w_guard.write_block(&mut w)?;
            opt.m.write_block(&mut w)?;
            opt.v.write_block(&mut w)?;
            // per-row lazy-Adam last-touch steps (dense params write 0 rows)
            for last in &opt.last_step {
                match last {
                    Some(rows) => {
                        write_u64_le(&mut w, rows.len() as u64)?;
                        for &x in rows {
                            write_u32_le(&mut w, x)?;
                        }
                    }
                    None => write_u64_le(&mut w, 0)?,
                }
            }
            w.flush()?;
        }
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {} into place", tmp.display()))?;
        Ok(())
    }

    /// Load a checkpoint into this store, replacing weights, moments and
    /// lazy-Adam state, and recomputing the maintained field norms.
    /// Accepts the full `CCKS` layout or a bare PR-1 `CCKP` params file
    /// (moments reset, step 0). Returns the stored optimizer step.
    ///
    /// The file is parsed into temporaries first and committed only once
    /// every block has read cleanly — a truncated or corrupt checkpoint
    /// leaves the store untouched.
    pub fn load_checkpoint(&self, path: &Path) -> Result<u64> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut r = BufReader::new(f);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        let step: u64;
        let params: ParamSet;
        let moments: Option<(ParamSet, ParamSet)>;
        let mut lazy: Option<Vec<Option<Vec<u32>>>> = None;
        if &magic == STORE_MAGIC {
            let version = read_u32_le(&mut r)?;
            ensure!(version == STORE_VERSION, "unsupported checkpoint version {version}");
            step = read_u64_le(&mut r)?;
            params = ParamSet::read_block(&mut r, &self.spec)?;
            let m = ParamSet::read_block(&mut r, &self.spec)?;
            let v = ParamSet::read_block(&mut r, &self.spec)?;
            moments = Some((m, v));
            let mut rows_per_param = Vec::with_capacity(self.spec.len());
            for e in &self.spec {
                let n = read_u64_le(&mut r)? as usize;
                if matches!(e.group.as_str(), "embed" | "wide") {
                    ensure!(
                        n == e.shape[0],
                        "checkpoint lazy rows {n} != {} for {}",
                        e.shape[0],
                        e.name
                    );
                    rows_per_param.push(Some(read_u32_vec(&mut r, n)?));
                } else {
                    ensure!(n == 0, "unexpected lazy rows for dense param {}", e.name);
                    rows_per_param.push(None);
                }
            }
            lazy = Some(rows_per_param);
        } else if &magic == CKPT_MAGIC {
            params = ParamSet::read_block_body(&mut r, &self.spec)?;
            moments = None;
            step = 0;
        } else {
            bail!("not a checkpoint file");
        }
        let sqnorms = init_sqnorms(&self.spec, &self.schema, &params)?;

        // everything parsed — commit atomically under the locks
        let mut w_guard = self.weights.write().unwrap();
        let mut opt = self.opt.lock().unwrap();
        let (m, v) = match moments {
            Some(mv) => mv,
            None => (params.zeros_like(), params.zeros_like()),
        };
        *w_guard = params;
        opt.m = m;
        opt.v = v;
        match lazy {
            Some(rows_per_param) => opt.last_step = rows_per_param,
            None => {
                for last in opt.last_step.iter_mut() {
                    if let Some(rows) = last {
                        rows.fill(0);
                    }
                }
            }
        }
        opt.field_sqnorms = sqnorms;
        Ok(step)
    }

    /// Params-only load, accepting both checkpoint formats (the `eval`
    /// command reads either a PR-1 `CCKP` file or a `CCKS` checkpoint).
    pub fn load_params(path: &Path, spec: &[ParamEntry]) -> Result<ParamSet> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut r = BufReader::new(f);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic == STORE_MAGIC {
            let version = read_u32_le(&mut r)?;
            ensure!(version == STORE_VERSION, "unsupported checkpoint version {version}");
            let _step = read_u64_le(&mut r)?;
            ParamSet::read_block(&mut r, spec)
        } else if &magic == CKPT_MAGIC {
            ParamSet::read_block_body(&mut r, spec)
        } else {
            bail!("not a checkpoint file");
        }
    }

    /// Maintained `Σw²` per field of the first `embed` table (tests and
    /// diagnostics; `None` when the spec has no embed group). Kept in
    /// sync with the weights only while the engine clips with `AdaField`
    /// — the sole reader; other modes skip the upkeep, and a checkpoint
    /// load recomputes the norms from the stored weights.
    pub fn field_sqnorms(&self) -> Option<Vec<f64>> {
        let opt = self.opt.lock().unwrap();
        opt.field_sqnorms.iter().find_map(|s| s.clone())
    }
}

/// One entry of an inspected checkpoint: tensor name + scalar count
/// (shapes are not stored in the file; resolve them against a spec).
#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointEntry {
    pub name: String,
    pub numel: u64,
}

/// Header-level summary of a checkpoint file, read without
/// materializing any payload (tensor data is seeked over) — the
/// `cowclip inspect` command's backing API, for sanity-checking an
/// artifact before serving it.
#[derive(Clone, Debug)]
pub struct CheckpointInfo {
    /// `"CCKS"` (full training state) or `"CCKP"` (bare params).
    pub format: &'static str,
    /// Store format version (0 for bare `CCKP` files).
    pub version: u32,
    /// Saved optimizer step (0 for bare `CCKP` files).
    pub step: u64,
    /// Name + numel per parameter tensor, in file order.
    pub params: Vec<CheckpointEntry>,
    /// Whether Adam moments + lazy-Adam rows follow the params block.
    pub has_moments: bool,
}

impl CheckpointInfo {
    /// Total parameter scalar count.
    pub fn total_numel(&self) -> u64 {
        self.params.iter().map(|e| e.numel).sum()
    }

    /// Total parameter payload bytes (f32).
    pub fn total_bytes(&self) -> u64 {
        self.total_numel() * 4
    }
}

/// Inspect a checkpoint file (either format) without loading payloads.
pub fn inspect_checkpoint(path: &Path) -> Result<CheckpointInfo> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic == STORE_MAGIC {
        let version = read_u32_le(&mut r)?;
        ensure!(version == STORE_VERSION, "unsupported checkpoint version {version}");
        let step = read_u64_le(&mut r)?;
        let params = scan_block(&mut r)?;
        // the "resumable" claim covers the moment and lazy-row blocks
        // too: scan (seek over) all of them so truncation anywhere in
        // the file is reported, not silently summarized
        for which in ["m", "v"] {
            let block = scan_block(&mut r)
                .with_context(|| format!("scanning the Adam {which} block"))?;
            ensure!(
                block.len() == params.len(),
                "Adam {which} block has {} tensors, params have {}",
                block.len(),
                params.len()
            );
        }
        for e in &params {
            let n = read_u64_le(&mut r)
                .with_context(|| format!("lazy-Adam rows for {}", e.name))?;
            r.seek(SeekFrom::Current(n as i64 * 4))?;
        }
        check_not_truncated(&mut r)?;
        Ok(CheckpointInfo { format: "CCKS", version, step, params, has_moments: true })
    } else if &magic == CKPT_MAGIC {
        let params = scan_block_body(&mut r)?;
        check_not_truncated(&mut r)?;
        Ok(CheckpointInfo { format: "CCKP", version: 0, step: 0, params, has_moments: false })
    } else {
        bail!("{}: not a checkpoint file", path.display());
    }
}

/// Seeking past EOF succeeds silently, so a truncated payload is caught
/// by comparing the cursor against the file length after the scan.
fn check_not_truncated(r: &mut BufReader<std::fs::File>) -> Result<()> {
    let pos = r.stream_position()?;
    let len = r.get_ref().metadata()?.len();
    ensure!(pos <= len, "checkpoint truncated: scan needs {pos} bytes, file has {len}");
    Ok(())
}

/// Scan one `CCKP` block (magic included), seeking over payloads.
fn scan_block<R: Read + Seek>(r: &mut R) -> Result<Vec<CheckpointEntry>> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    ensure!(&magic == CKPT_MAGIC, "malformed checkpoint block");
    scan_block_body(r)
}

fn scan_block_body<R: Read + Seek>(r: &mut R) -> Result<Vec<CheckpointEntry>> {
    let n = read_u32_le(r)? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let name_len = read_u32_le(r)? as usize;
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let numel = read_u64_le(r)?;
        r.seek(SeekFrom::Current(numel as i64 * 4))
            .context("checkpoint truncated inside a tensor payload")?;
        out.push(CheckpointEntry { name: String::from_utf8(name)?, numel });
    }
    Ok(out)
}

/// A vocab-table work item's gradient payload.
enum TableGrad<'a> {
    /// A fully merged gradient range (the eager path): ids, mutable
    /// values, and the whole-table counts + range end — the per-range
    /// clip-count resolution runs inside the shard task
    /// ([`counts_for_range`] in [`run_shard`]), off the leader's serial
    /// prefix.
    Ready { ids: &'a [u32], vals: &'a mut [f32], counts: &'a SparseRows, hi: usize },
    /// The two halves of a deferred root merge, sliced to this shard's
    /// row range; the shard thread performs the union merge itself (the
    /// row-local arithmetic is bitwise identical to merging the whole
    /// tables first — see [`merge_row_slices`]), so apply work on this
    /// range starts without waiting for the whole-table merge tail.
    Merge {
        l_ids: &'a [u32],
        l_vals: &'a [f32],
        r_ids: &'a [u32],
        r_vals: &'a [f32],
        /// Count ranges of both halves (empty for un-clipped tables).
        lc: (&'a [u32], &'a [f32]),
        rc: (&'a [u32], &'a [f32]),
    },
}

/// One shard's slice of the apply-stage work: disjoint mutable views of
/// the parameters, moments and gradients it owns.
enum WorkItem<'a> {
    /// A row range of a vocab-shaped table (embed/wide).
    VocabTable {
        base: usize,
        d: usize,
        grad: TableGrad<'a>,
        w: &'a mut [f32],
        m: &'a mut [f32],
        v: &'a mut [f32],
        last: &'a mut [u32],
        /// `(global_offset, vocab)` of the fields inside the range
        /// (empty for the un-clipped wide table).
        fields: &'a [(usize, usize)],
        sqnorms: Option<&'a mut [f64]>,
        /// Clip this table (embed group only).
        clip: bool,
        global_norm: Option<f32>,
        lr: f32,
    },
    /// A whole dense tensor (MLP/cross weights, biases).
    DenseTensor {
        w: &'a mut [f32],
        m: &'a mut [f32],
        v: &'a mut [f32],
        g: &'a mut [f32],
        lr: f32,
    },
}

/// Run the per-shard work — serially, or bucketed round-robin over at
/// most `threads` scoped threads (shards can outnumber cores).
fn run_shards(work: Vec<Vec<WorkItem<'_>>>, ctx: &ApplyCtx, threads: usize) -> Result<()> {
    let n_shards = work.len();
    let run_threads = threads.min(n_shards).max(1);
    if run_threads <= 1 {
        for items in work {
            run_shard(items, ctx)?;
        }
    } else {
        let mut buckets: Vec<Vec<Vec<WorkItem<'_>>>> =
            (0..run_threads).map(|_| Vec::new()).collect();
        for (s, items) in work.into_iter().enumerate() {
            if !items.is_empty() {
                buckets[s % run_threads].push(items);
            }
        }
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::with_capacity(run_threads);
            for bucket in buckets {
                if bucket.is_empty() {
                    continue;
                }
                handles.push(scope.spawn(move || -> Result<()> {
                    for items in bucket {
                        run_shard(items, ctx)?;
                    }
                    Ok(())
                }));
            }
            for h in handles {
                h.join().expect("shard apply thread panicked")?;
            }
            Ok(())
        })?;
    }
    Ok(())
}

/// The per-range `clip → lazy L2 → lazy Adam` core, identical math to
/// the serial oracle (`ReferenceEngine::apply`) on each slice.
#[allow(clippy::too_many_arguments)]
fn apply_table_range(
    ctx: &ApplyCtx,
    base: usize,
    d: usize,
    ids: &[u32],
    gvals: &mut [f32],
    cnt: &[f32],
    w: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    last: &mut [u32],
    fields: &[(usize, usize)],
    mut sqnorms: Option<&mut [f64]>,
    clip: bool,
    global_norm: Option<f32>,
    lr: f32,
) {
    if clip {
        let _clip = crate::obs::span(crate::obs::Phase::Clip);
        clip_embedding_grads_range(
            ctx.clip,
            ids,
            gvals,
            d,
            w,
            base,
            cnt,
            fields,
            sqnorms.as_deref(),
            global_norm,
            &ctx.clip_params,
        );
    }
    let _apply = crate::obs::span(crate::obs::Phase::Apply);
    // lazy L2: regularize touched rows only (serial-oracle semantics
    // for sparse payloads)
    for (k, &id) in ids.iter().enumerate() {
        let lo = (id as usize - base) * d;
        for j in 0..d {
            gvals[k * d + j] += ctx.l2_embed * w[lo + j];
        }
    }
    // maintained field norms: retire the touched rows' old mass,
    // update, then add the new mass back. Only AdaField reads these
    // (the clip mode is fixed per engine, and a checkpoint load
    // recomputes from the weights), so other modes skip the two extra
    // O(touched·d) passes.
    let track_norms = ctx.clip == ClipMode::AdaField;
    if track_norms {
        if let Some(sq) = sqnorms.as_deref_mut() {
            update_field_sqnorms(sq, fields, ids, w, base, d, -1.0);
        }
    }
    lazy_step_rows(&ctx.adam, w, m, v, last, ids, gvals, d, lr, ctx.step, base);
    if track_norms {
        if let Some(sq) = sqnorms.as_deref_mut() {
            update_field_sqnorms(sq, fields, ids, w, base, d, 1.0);
        }
    }
}

/// Execute one shard's items. For [`TableGrad::Merge`] payloads the
/// shard performs its slice of the deferred root merge first — this is
/// where the reduction's final merge overlaps the optimizer.
fn run_shard(items: Vec<WorkItem<'_>>, ctx: &ApplyCtx) -> Result<()> {
    let adam = Adam::new(ctx.adam);
    for item in items {
        match item {
            WorkItem::DenseTensor { w, m, v, g, lr } => {
                let _apply = crate::obs::span(crate::obs::Phase::Apply);
                adam.step(w, m, v, g, lr, ctx.step as f32);
            }
            WorkItem::VocabTable {
                base,
                d,
                grad,
                w,
                m,
                v,
                last,
                fields,
                sqnorms,
                clip,
                global_norm,
                lr,
            } => match grad {
                TableGrad::Ready { ids, vals, counts, hi } => {
                    if ids.is_empty() {
                        continue;
                    }
                    let cnt: Cow<'_, [f32]> = if clip {
                        counts_for_range(counts, ids, base, hi)
                    } else {
                        Cow::Borrowed(&[][..])
                    };
                    apply_table_range(
                        ctx, base, d, ids, vals, &cnt, w, m, v, last, fields, sqnorms,
                        clip, global_norm, lr,
                    );
                }
                TableGrad::Merge { l_ids, l_vals, r_ids, r_vals, lc, rc } => {
                    let (ids, mut vals) = merge_row_slices(l_ids, l_vals, r_ids, r_vals, d);
                    if ids.is_empty() {
                        continue;
                    }
                    let cnt: Vec<f32> = if clip {
                        let (cids, cvals) = merge_row_slices(lc.0, lc.1, rc.0, rc.1, 1);
                        if cids == ids {
                            cvals
                        } else {
                            // counts support differs from the grad's
                            // (never on the trainer path): align by lookup
                            ids.iter()
                                .map(|id| {
                                    cids.binary_search(id)
                                        .map_or(0.0, |k| cvals[k])
                                })
                                .collect()
                        }
                    } else {
                        Vec::new()
                    };
                    apply_table_range(
                        ctx, base, d, &ids, &mut vals, &cnt, w, m, v, last, fields,
                        sqnorms, clip, global_norm, lr,
                    );
                }
            },
        }
    }
    Ok(())
}

/// `sq[field] += sign * Σ row²` over the touched rows, walking fields and
/// sorted ids in lockstep (same two-pointer walk as the clip twins).
fn update_field_sqnorms(
    sq: &mut [f64],
    fields: &[(usize, usize)],
    ids: &[u32],
    w: &[f32],
    base: usize,
    d: usize,
    sign: f64,
) {
    let mut k = 0usize;
    for (fi, &(off, vs)) in fields.iter().enumerate() {
        let hi_id = (off + vs) as u32;
        while k < ids.len() && ids[k] < hi_id {
            let lo = (ids[k] as usize - base) * d;
            let mass: f64 = w[lo..lo + d].iter().map(|&x| (x as f64) * (x as f64)).sum();
            sq[fi] += sign * mass;
            k += 1;
        }
    }
    debug_assert_eq!(k, ids.len(), "touched ids outside the shard's fields");
}

/// Per-stored-row counts aligned with `ids`: borrowed when the counts'
/// ids over `[lo, hi)` are exactly `ids` (true for trainer-produced
/// payloads), materialized by lookup otherwise.
fn counts_for_range<'a>(
    counts: &'a SparseRows,
    ids: &[u32],
    lo: usize,
    hi: usize,
) -> Cow<'a, [f32]> {
    let a = counts.ids().partition_point(|&id| (id as usize) < lo);
    let b = counts.ids().partition_point(|&id| (id as usize) < hi);
    if &counts.ids()[a..b] == ids {
        Cow::Borrowed(&counts.vals()[a..b])
    } else {
        Cow::Owned(ids.iter().map(|&id| counts.value_at(id)).collect())
    }
}

/// Split a packed `[rows, d]` slice into per-shard row ranges. `ranges`
/// must be contiguous ascending and start at row 0 (the `ShardPlan`
/// invariant).
fn split_rows<'a, T>(s: &'a mut [T], d: usize, ranges: &[(usize, usize)]) -> Vec<&'a mut [T]> {
    debug_assert_eq!(ranges.first().map_or(0, |r| r.0), 0);
    let mut out = Vec::with_capacity(ranges.len());
    let mut rest = s;
    for &(lo, hi) in ranges {
        debug_assert!(hi >= lo);
        let (take, r) = std::mem::take(&mut rest).split_at_mut((hi - lo) * d);
        out.push(take);
        rest = r;
    }
    debug_assert!(rest.is_empty(), "ranges must cover the whole table");
    out
}

/// Split a slice at ascending cut points (`cuts[0] == 0`,
/// `cuts.last() == len`).
fn split_by_cuts<'a, T>(s: &'a mut [T], cuts: &[usize]) -> Vec<&'a mut [T]> {
    let mut out = Vec::with_capacity(cuts.len().saturating_sub(1));
    let mut rest = s;
    for win in cuts.windows(2) {
        let (take, r) = std::mem::take(&mut rest).split_at_mut(win[1] - win[0]);
        out.push(take);
        rest = r;
    }
    debug_assert!(rest.is_empty());
    out
}

/// Per-field `Σw²` (f64) for every `embed`-group table.
fn init_sqnorms(
    spec: &[ParamEntry],
    schema: &Schema,
    params: &ParamSet,
) -> Result<Vec<Option<Vec<f64>>>> {
    let mut out = Vec::with_capacity(spec.len());
    for (e, t) in spec.iter().zip(&params.tensors) {
        if e.group == "embed" {
            let d = e.shape[1];
            let w = t.as_f32()?;
            let sq: Vec<f64> = schema
                .fields()
                .map(|(off, vs)| {
                    w[off * d..(off + vs) * d].iter().map(|&x| (x as f64) * (x as f64)).sum()
                })
                .collect();
            out.push(Some(sq));
        } else {
            out.push(None);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::{init_params, InitConfig};
    use crate::scaling::rules::HyperSet;
    use crate::util::Rng;

    fn test_schema() -> Schema {
        Schema { name: "store_test".into(), n_dense: 2, vocab_sizes: vec![12, 9, 6, 4, 2] }
    }

    fn test_spec(schema: &Schema, d: usize) -> Vec<ParamEntry> {
        let v = schema.total_vocab();
        vec![
            ParamEntry { name: "embed_table".into(), shape: vec![v, d], group: "embed".into() },
            ParamEntry { name: "wide_table".into(), shape: vec![v, 1], group: "wide".into() },
            ParamEntry { name: "mlp_w0".into(), shape: vec![8, 4], group: "dense".into() },
            ParamEntry { name: "mlp_b0".into(), shape: vec![4], group: "dense".into() },
            ParamEntry { name: "mlp_w1".into(), shape: vec![4, 1], group: "dense".into() },
        ]
    }

    fn ctx(clip: ClipMode, step: u32) -> ApplyCtx {
        let h = HyperSet {
            lr_dense: 1e-2,
            lr_embed: 8e-3,
            l2_embed: 1e-4,
            clip_r: 1.0,
            clip_zeta: 1e-4,
            clip_t: 0.5,
        };
        ApplyCtx {
            clip,
            clip_params: ClipParams { r: h.clip_r, zeta: h.clip_zeta, clip_t: h.clip_t },
            lr_embed: h.lr_embed,
            lr_dense: h.lr_dense,
            l2_embed: h.l2_embed,
            adam: AdamConfig::default(),
            step,
        }
    }

    /// Random sparse grads + counts for the two vocab tables and dense
    /// grads for the rest, Criteo-shaped (few touched rows).
    fn random_grads(
        spec: &[ParamEntry],
        schema: &Schema,
        seed: u64,
    ) -> (Vec<GradTensor>, SparseRows) {
        let v = schema.total_vocab();
        let mut rng = Rng::new(seed);
        let ids: Vec<u32> = (0..v as u32).filter(|_| rng.bernoulli(0.3)).collect();
        let counts: Vec<f32> = ids.iter().map(|_| 1.0 + rng.below(5) as f32).collect();
        let grads = spec
            .iter()
            .map(|e| match e.group.as_str() {
                "embed" | "wide" => {
                    let d = e.numel() / e.shape[0];
                    let vals: Vec<f32> =
                        (0..ids.len() * d).map(|_| rng.next_gaussian() as f32).collect();
                    GradTensor::Sparse(SparseRows::new(v, d, ids.clone(), vals))
                }
                _ => {
                    let vals: Vec<f32> =
                        (0..e.numel()).map(|_| rng.next_gaussian() as f32 * 0.1).collect();
                    GradTensor::Dense(Tensor::f32(e.shape.clone(), vals))
                }
            })
            .collect();
        (grads, SparseRows::new(v, 1, ids, counts))
    }

    #[test]
    fn plan_is_field_aligned_and_covering() {
        let schema = test_schema();
        let spec = test_spec(&schema, 4);
        for n in [1usize, 2, 3, 5, 8] {
            let plan = ShardPlan::build(&spec, &schema, n).unwrap();
            let ranges = plan.row_ranges();
            assert_eq!(ranges.len(), n);
            assert_eq!(ranges[0].0, 0);
            assert_eq!(ranges[n - 1].1, schema.total_vocab());
            let offsets = schema.offsets();
            for (s, &(lo, hi)) in ranges.iter().enumerate() {
                assert!(lo <= hi);
                if s > 0 {
                    assert_eq!(lo, ranges[s - 1].1, "ranges must be contiguous");
                }
                // every boundary is a field offset (or the vocab end)
                assert!(
                    lo == schema.total_vocab() || offsets.contains(&lo),
                    "shard {s} starts mid-field at {lo}"
                );
                let (flo, fhi) = plan.field_span(s);
                assert!(flo <= fhi);
                if flo < fhi {
                    assert_eq!(offsets[flo], lo);
                }
            }
        }
    }

    #[test]
    fn plan_balances_dense_params() {
        let schema = test_schema();
        let spec = test_spec(&schema, 4);
        let plan = ShardPlan::build(&spec, &schema, 2).unwrap();
        // three dense tensors (32, 4, 4 scalars) over two shards: the big
        // one alone, the two small ones together
        let owners: Vec<usize> = plan
            .assignments
            .iter()
            .filter_map(|a| match a {
                Assignment::Whole(s) => Some(*s),
                Assignment::Rows => None,
            })
            .collect();
        assert_eq!(owners.len(), 3);
        assert_eq!(owners[0], 0);
        assert_eq!(owners[1], 1);
        assert_eq!(owners[2], 1);
    }

    #[test]
    fn sharded_apply_matches_single_shard_all_modes() {
        let schema = test_schema();
        let d = 4;
        let spec = test_spec(&schema, d);
        for clip in ClipMode::ALL {
            let init = init_params(&spec, &InitConfig { seed: 11, embed_sigma: 0.02 });
            let serial = ParamStore::new(schema.clone(), init.clone(), 1).unwrap();
            let sharded = ParamStore::new(schema.clone(), init, 3).unwrap();
            for t in 1..=5u32 {
                let (mut g1, counts) = random_grads(&spec, &schema, 40 + t as u64);
                let mut g2 = g1.clone();
                serial.apply_sharded(&ctx(clip, t), &mut g1, &counts, 1).unwrap();
                sharded.apply_sharded(&ctx(clip, t), &mut g2, &counts, 3).unwrap();
            }
            let a = serial.snapshot();
            let b = sharded.snapshot();
            for (i, (ta, tb)) in a.tensors.iter().zip(&b.tensors).enumerate() {
                assert_eq!(ta, tb, "{clip}: param[{i}] diverged across shard counts");
            }
            let (ma, va) = serial.moments();
            let (mb, vb) = sharded.moments();
            assert_eq!(ma.tensors, mb.tensors, "{clip}: m moments");
            assert_eq!(va.tensors, vb.tensors, "{clip}: v moments");
        }
    }

    #[test]
    fn dense_vocab_grads_take_the_eager_path() {
        // a densified embed grad must update *every* row (eager Adam
        // semantics), unlike the sparse payload which freezes untouched rows
        let schema = test_schema();
        let d = 2;
        let spec = test_spec(&schema, d);
        let init = init_params(&spec, &InitConfig { seed: 3, embed_sigma: 0.05 });
        let store = ParamStore::new(schema.clone(), init.clone(), 2).unwrap();
        let v = schema.total_vocab();
        let (mut grads, counts) = random_grads(&spec, &schema, 7);
        // densify the embed grad (zero rows included)
        let GradTensor::Sparse(s) = &grads[0] else { panic!() };
        grads[0] = GradTensor::Dense(s.to_tensor());
        store.apply_sharded(&ctx(ClipMode::None, 1), &mut grads, &counts, 2).unwrap();
        let after = store.snapshot();
        let w0 = init.tensors[0].as_f32().unwrap();
        let w1 = after.tensors[0].as_f32().unwrap();
        // with L2 > 0 every row moves, even zero-grad ones
        let moved = (0..v).filter(|&r| w0[r * d..(r + 1) * d] != w1[r * d..(r + 1) * d]).count();
        assert!(moved > v * 9 / 10, "only {moved}/{v} rows moved on the eager path");
    }

    #[test]
    fn maintained_sqnorms_track_the_weights() {
        let schema = test_schema();
        let d = 3;
        let spec = test_spec(&schema, d);
        let init = init_params(&spec, &InitConfig { seed: 5, embed_sigma: 0.03 });
        let store = ParamStore::new(schema.clone(), init, 2).unwrap();
        for t in 1..=6u32 {
            let (mut grads, counts) = random_grads(&spec, &schema, 90 + t as u64);
            store.apply_sharded(&ctx(ClipMode::AdaField, t), &mut grads, &counts, 2).unwrap();
        }
        let maintained = store.field_sqnorms().unwrap();
        let w_set = store.snapshot();
        let w = w_set.tensors[0].as_f32().unwrap();
        for (fi, (off, vs)) in schema.fields().enumerate() {
            let fresh: f64 =
                w[off * d..(off + vs) * d].iter().map(|&x| (x as f64) * (x as f64)).sum();
            let diff = (maintained[fi] - fresh).abs();
            assert!(
                diff <= 1e-9 * fresh.max(1.0),
                "field {fi}: maintained {} vs fresh {fresh}",
                maintained[fi]
            );
        }
    }

    /// The deferred-root-merge apply (merge the two reduction halves per
    /// shard row range inside the shard task) must be bitwise identical
    /// to eagerly merging the halves and applying the total — for every
    /// clip mode (Global exercises the fallback) and shard count.
    #[test]
    fn apply_sharded_pair_matches_eager_merge_all_modes() {
        let schema = test_schema();
        let d = 4;
        let spec = test_spec(&schema, d);
        for clip in ClipMode::ALL {
            for shards in [1usize, 2, 3] {
                let init = init_params(&spec, &InitConfig { seed: 17, embed_sigma: 0.02 });
                let eager = ParamStore::new(schema.clone(), init.clone(), shards).unwrap();
                let pair = ParamStore::new(schema.clone(), init, shards).unwrap();
                for t in 1..=4u32 {
                    // two halves with overlapping + disjoint touched ids
                    let (gl, cl) = random_grads(&spec, &schema, 700 + t as u64);
                    let (gr, cr) = random_grads(&spec, &schema, 900 + t as u64);

                    // eager: merge halves first (the TreeReducer root
                    // merge), then the normal sharded apply
                    let mut merged = gl.clone();
                    for (a, b) in merged.iter_mut().zip(&gr) {
                        a.axpy(1.0, b).unwrap();
                    }
                    let mut counts = cl.clone();
                    counts.axpy(1.0, &cr).unwrap();
                    eager.apply_sharded(&ctx(clip, t), &mut merged, &counts, shards).unwrap();

                    // pair: merge happens inside the shard tasks
                    let mut left = gl;
                    pair.apply_sharded_pair(&ctx(clip, t), &mut left, gr, &cl, &cr, shards)
                        .unwrap();
                }
                let a = eager.snapshot();
                let b = pair.snapshot();
                for (i, (ta, tb)) in a.tensors.iter().zip(&b.tensors).enumerate() {
                    assert_eq!(ta, tb, "{clip}/shards={shards}: param[{i}] diverged");
                }
                let (ma, va) = eager.moments();
                let (mb, vb) = pair.moments();
                assert_eq!(ma.tensors, mb.tensors, "{clip}/shards={shards}: m moments");
                assert_eq!(va.tensors, vb.tensors, "{clip}/shards={shards}: v moments");
            }
        }
    }

    #[test]
    fn checkpoint_roundtrip_full_state() {
        let schema = test_schema();
        let spec = test_spec(&schema, 4);
        let init = init_params(&spec, &InitConfig { seed: 21, embed_sigma: 0.02 });
        let store = ParamStore::new(schema.clone(), init, 2).unwrap();
        for t in 1..=3u32 {
            let (mut grads, counts) = random_grads(&spec, &schema, t as u64);
            store.apply_sharded(&ctx(ClipMode::CowClip, t), &mut grads, &counts, 1).unwrap();
        }
        let dir = std::env::temp_dir().join(format!("ccks_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("full.ckpt");
        store.save_checkpoint(&path, 3).unwrap();

        // load into a store with a *different* shard count
        let fresh = init_params(&spec, &InitConfig { seed: 99, embed_sigma: 0.02 });
        let other = ParamStore::new(schema.clone(), fresh, 3).unwrap();
        let step = other.load_checkpoint(&path).unwrap();
        assert_eq!(step, 3);
        assert_eq!(other.snapshot().tensors, store.snapshot().tensors);
        let (m1, v1) = store.moments();
        let (m2, v2) = other.moments();
        assert_eq!(m1.tensors, m2.tensors);
        assert_eq!(v1.tensors, v2.tensors);
        {
            let a = store.opt.lock().unwrap();
            let b = other.opt.lock().unwrap();
            assert_eq!(a.last_step, b.last_step, "lazy-Adam rows must round-trip");
        }
        // params-only reader sees the same weights
        let p = ParamStore::load_params(&path, &spec).unwrap();
        assert_eq!(p.tensors, store.snapshot().tensors);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn inspect_reads_both_formats_without_payloads() {
        let schema = test_schema();
        let spec = test_spec(&schema, 4);
        let init = init_params(&spec, &InitConfig { seed: 13, embed_sigma: 0.02 });
        let dir = std::env::temp_dir().join(format!("ccks_inspect_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();

        let cckp = dir.join("params.ckpt");
        init.save(&cckp).unwrap();
        let info = inspect_checkpoint(&cckp).unwrap();
        assert_eq!(info.format, "CCKP");
        assert_eq!(info.step, 0);
        assert!(!info.has_moments);
        assert_eq!(info.params.len(), spec.len());

        let store = ParamStore::new(schema.clone(), init, 2).unwrap();
        let ccks = dir.join("full.ckpt");
        store.save_checkpoint(&ccks, 42).unwrap();
        let info = inspect_checkpoint(&ccks).unwrap();
        assert_eq!(info.format, "CCKS");
        assert_eq!(info.step, 42);
        assert!(info.has_moments);
        for (e, s) in info.params.iter().zip(&spec) {
            assert_eq!(e.name, s.name);
            assert_eq!(e.numel, s.numel() as u64);
        }
        assert_eq!(info.total_bytes(), 4 * spec.iter().map(|e| e.numel() as u64).sum::<u64>());

        // a truncated file is reported, not silently summarized —
        // whether the cut lands in the params block, in the moment /
        // lazy-row blocks ("resumable" must mean the whole state is
        // there), or mid-payload anywhere
        let bytes = std::fs::read(&ccks).unwrap();
        for cut_at in [60, bytes.len() / 2, bytes.len() - 10] {
            let cut = dir.join("cut.ckpt");
            std::fs::write(&cut, &bytes[..cut_at]).unwrap();
            assert!(inspect_checkpoint(&cut).is_err(), "cut at {cut_at} must be reported");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bare_cckp_file_loads_with_reset_moments() {
        let schema = test_schema();
        let spec = test_spec(&schema, 4);
        let params = init_params(&spec, &InitConfig { seed: 8, embed_sigma: 0.02 });
        let dir = std::env::temp_dir().join(format!("cckp_compat_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("params.ckpt");
        params.save(&path).unwrap();

        let store = ParamStore::new(
            schema.clone(),
            init_params(&spec, &InitConfig { seed: 1, embed_sigma: 0.02 }),
            2,
        )
        .unwrap();
        let step = store.load_checkpoint(&path).unwrap();
        assert_eq!(step, 0);
        assert_eq!(store.snapshot().tensors, params.tensors);
        let (m, v) = store.moments();
        assert!(m.tensors.iter().all(|t| t.as_f32().unwrap().iter().all(|&x| x == 0.0)));
        assert!(v.tensors.iter().all(|t| t.as_f32().unwrap().iter().all(|&x| x == 0.0)));
        // and ParamStore::load_params accepts the same file
        let p = ParamStore::load_params(&path, &spec).unwrap();
        assert_eq!(p.tensors, params.tensors);
        std::fs::remove_dir_all(&dir).ok();
    }
}
