//! `artifacts/manifest.json` — the compile-path/Rust interface contract.
//!
//! Written by `python/compile/manifest.py`; every field the Rust side
//! relies on is validated on load, and the schema embedded here is
//! cross-checked against the Rust presets by an integration test so the
//! two sides cannot drift silently. Parsed with the in-tree JSON reader
//! (`util::json`) — the build environment is offline, no serde.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::data::schema::Schema;
use crate::util::json::Json;

pub const SUPPORTED_VERSION: usize = 2;

/// One positional parameter of a model.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    /// "embed" | "wide" | "dense" — drives LR group / L2 / clipping.
    pub group: String,
}

impl ParamEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<ParamEntry> {
        Ok(ParamEntry {
            name: v.get("name")?.as_str()?.to_string(),
            shape: v.get("shape")?.usize_vec()?,
            group: v.get("group")?.as_str()?.to_string(),
        })
    }
}

/// One positional input of an HLO program.
#[derive(Clone, Debug)]
pub struct InputDesc {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

/// One lowered HLO program.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub id: String,
    pub kind: String, // grad | apply | fwd
    pub model: String,
    pub schema: String,
    pub batch: Option<usize>,
    pub clip: Option<String>,
    pub file: String,
    pub inputs: Vec<InputDesc>,
    pub n_outputs: usize,
}

/// Architecture constants shared by every artifact.
#[derive(Clone, Debug)]
pub struct ModelCfg {
    pub embed_dim: usize,
    pub hidden: Vec<usize>,
    pub n_cross: usize,
    pub use_pallas: bool,
}

/// Adam constants baked into the apply programs.
#[derive(Clone, Debug)]
pub struct AdamCfg {
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
}

/// The full manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub version: usize,
    pub model_cfg: ModelCfg,
    pub adam: AdamCfg,
    pub hypers_layout: Vec<String>,
    schemas: HashMap<String, Schema>,
    pub param_specs: HashMap<String, Vec<ParamEntry>>,
    pub artifacts: Vec<Artifact>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let v = Json::parse(&text).context("parsing manifest.json")?;

        let version = v.get("version")?.as_usize()?;
        let mc = v.get("model_cfg")?;
        let model_cfg = ModelCfg {
            embed_dim: mc.get("embed_dim")?.as_usize()?,
            hidden: mc.get("hidden")?.usize_vec()?,
            n_cross: mc.get("n_cross")?.as_usize()?,
            use_pallas: mc.get("use_pallas")?.as_bool()?,
        };
        let ad = v.get("adam")?;
        let adam = AdamCfg {
            beta1: ad.get("beta1")?.as_f64()?,
            beta2: ad.get("beta2")?.as_f64()?,
            eps: ad.get("eps")?.as_f64()?,
        };
        let hypers_layout = v.get("hypers_layout")?.string_vec()?;

        let mut schemas = HashMap::new();
        for (name, sj) in v.get("schemas")?.as_obj()? {
            let schema = Schema {
                name: sj.get("name")?.as_str()?.to_string(),
                n_dense: sj.get("n_dense")?.as_usize()?,
                vocab_sizes: sj.get("vocab_sizes")?.usize_vec()?,
            };
            let total = sj.get("total_vocab")?.as_usize()?;
            if total != schema.total_vocab() {
                bail!("schema {name}: inconsistent total_vocab");
            }
            schemas.insert(name.clone(), schema);
        }

        let mut param_specs = HashMap::new();
        for (key, spec) in v.get("param_specs")?.as_obj()? {
            let entries: Vec<ParamEntry> = spec
                .as_arr()?
                .iter()
                .map(ParamEntry::from_json)
                .collect::<Result<_>>()?;
            param_specs.insert(key.clone(), entries);
        }

        let mut artifacts = Vec::new();
        for a in v.get("artifacts")?.as_arr()? {
            let inputs = a
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(|i| {
                    Ok(InputDesc {
                        name: i.get("name")?.as_str()?.to_string(),
                        dtype: i.get("dtype")?.as_str()?.to_string(),
                        shape: i.get("shape")?.usize_vec()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(Artifact {
                id: a.get("id")?.as_str()?.to_string(),
                kind: a.get("kind")?.as_str()?.to_string(),
                model: a.get("model")?.as_str()?.to_string(),
                schema: a.get("schema")?.as_str()?.to_string(),
                batch: match a.opt("batch") {
                    Some(b) => Some(b.as_usize()?),
                    None => None,
                },
                clip: match a.opt("clip") {
                    Some(c) => Some(c.as_str()?.to_string()),
                    None => None,
                },
                file: a.get("file")?.as_str()?.to_string(),
                inputs,
                n_outputs: a.get("n_outputs")?.as_usize()?,
            });
        }

        let m = Manifest {
            version,
            model_cfg,
            adam,
            hypers_layout,
            schemas,
            param_specs,
            artifacts,
            dir: dir.to_path_buf(),
        };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        if self.version != SUPPORTED_VERSION {
            bail!(
                "manifest version {} unsupported (want {}); re-run `make artifacts`",
                self.version,
                SUPPORTED_VERSION
            );
        }
        let expected = [
            "lr_dense", "lr_embed", "l2_embed", "clip_r", "clip_zeta", "clip_t", "step",
            "reserved",
        ];
        if self.hypers_layout != expected {
            bail!("hypers layout drifted: {:?}", self.hypers_layout);
        }
        for a in &self.artifacts {
            if !matches!(a.kind.as_str(), "grad" | "apply" | "fwd") {
                bail!("artifact {}: unknown kind {}", a.id, a.kind);
            }
            if a.inputs.is_empty() || a.n_outputs == 0 {
                bail!("artifact {}: empty interface", a.id);
            }
        }
        Ok(())
    }

    /// Schema by name, as the Rust type.
    pub fn schema(&self, name: &str) -> Result<Schema> {
        self.schemas
            .get(name)
            .cloned()
            .with_context(|| format!("schema {name} not in manifest"))
    }

    pub fn schema_names(&self) -> Vec<&str> {
        self.schemas.keys().map(|s| s.as_str()).collect()
    }

    /// Parameter spec for a (schema, model) pair.
    pub fn param_spec(&self, schema: &str, model: &str) -> Result<&[ParamEntry]> {
        self.param_specs
            .get(&format!("{schema}-{model}"))
            .map(|v| v.as_slice())
            .with_context(|| format!("no param spec for {schema}-{model}"))
    }

    /// Find an artifact by predicate fields.
    pub fn find(
        &self,
        kind: &str,
        model: &str,
        schema: &str,
        batch: Option<usize>,
        clip: Option<&str>,
    ) -> Result<&Artifact> {
        self.artifacts
            .iter()
            .find(|a| {
                a.kind == kind
                    && a.model == model
                    && a.schema == schema
                    && (batch.is_none() || a.batch == batch)
                    && (clip.is_none() || a.clip.as_deref() == clip)
            })
            .with_context(|| {
                format!("artifact not found: kind={kind} model={model} schema={schema} batch={batch:?} clip={clip:?}")
            })
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, artifact: &Artifact) -> PathBuf {
        self.dir.join(&artifact.file)
    }

    /// Microbatch sizes available for (model, schema) grad programs,
    /// ascending.
    pub fn grad_microbatches(&self, model: &str, schema: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == "grad" && a.model == model && a.schema == schema)
            .filter_map(|a| a.batch)
            .collect();
        v.sort_unstable();
        v
    }
}
