//! Positional parameter set with checkpoint (de)serialization.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::manifest::ParamEntry;
use crate::tensor::Tensor;
use crate::wire::codec::{read_f32_vec, read_u32_le, read_u64_le, write_u32_le, write_u64_le};

pub(crate) const CKPT_MAGIC: &[u8; 4] = b"CCKP";

/// Ordered model parameters (or Adam moments) matching a manifest spec.
#[derive(Clone, Debug)]
pub struct ParamSet {
    pub spec: Vec<ParamEntry>,
    pub tensors: Vec<Tensor>,
}

impl ParamSet {
    pub fn new(spec: Vec<ParamEntry>, tensors: Vec<Tensor>) -> Result<ParamSet> {
        if spec.len() != tensors.len() {
            bail!("spec/tensor arity mismatch: {} vs {}", spec.len(), tensors.len());
        }
        for (e, t) in spec.iter().zip(&tensors) {
            if e.shape != t.shape() {
                bail!("param {}: shape {:?} vs tensor {:?}", e.name, e.shape, t.shape());
            }
        }
        Ok(ParamSet { spec, tensors })
    }

    /// All-zeros set with the same spec (Adam moment initialization).
    pub fn zeros_like(&self) -> ParamSet {
        ParamSet {
            spec: self.spec.clone(),
            tensors: self.spec.iter().map(|e| Tensor::zeros(&e.shape)).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Total scalar parameter count.
    pub fn numel(&self) -> usize {
        self.spec.iter().map(|e| e.numel()).sum()
    }

    /// Scalar count per group ("embed"/"wide"/"dense").
    pub fn numel_group(&self, group: &str) -> usize {
        self.spec
            .iter()
            .filter(|e| e.group == group)
            .map(|e| e.numel())
            .sum()
    }

    pub fn by_name(&self, name: &str) -> Option<&Tensor> {
        self.spec
            .iter()
            .position(|e| e.name == name)
            .map(|i| &self.tensors[i])
    }

    pub fn by_name_mut(&mut self, name: &str) -> Option<&mut Tensor> {
        self.spec
            .iter()
            .position(|e| e.name == name)
            .map(|i| &mut self.tensors[i])
    }

    /// Save to a simple binary checkpoint (names + f32 payloads).
    pub fn save(&self, path: &Path) -> Result<()> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut w = BufWriter::new(f);
        self.write_block(&mut w)?;
        w.flush()?;
        Ok(())
    }

    /// Serialize as one self-describing `CCKP` block (magic + names +
    /// f32 payloads) — the byte layout [`ParamSet::save`] has always
    /// written; the sharded [`super::store::ParamStore`] checkpoint
    /// embeds three of these back to back.
    pub fn write_block<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_all(CKPT_MAGIC)?;
        write_u32_le(w, self.len() as u32)?;
        for (e, t) in self.spec.iter().zip(&self.tensors) {
            let name = e.name.as_bytes();
            write_u32_le(w, name.len() as u32)?;
            w.write_all(name)?;
            write_u64_le(w, t.len() as u64)?;
            for &x in t.as_f32()? {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    /// Load a checkpoint against a known spec (shape-checked).
    pub fn load(path: &Path, spec: &[ParamEntry]) -> Result<ParamSet> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut r = BufReader::new(f);
        Self::read_block(&mut r, spec)
    }

    /// Read one `CCKP` block (magic included) against a known spec.
    pub fn read_block<R: Read>(r: &mut R, spec: &[ParamEntry]) -> Result<ParamSet> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != CKPT_MAGIC {
            bail!("not a checkpoint file");
        }
        Self::read_block_body(r, spec)
    }

    /// Read a `CCKP` block whose magic has already been consumed.
    pub(crate) fn read_block_body<R: Read>(r: &mut R, spec: &[ParamEntry]) -> Result<ParamSet> {
        let n = read_u32_le(r)? as usize;
        if n != spec.len() {
            bail!("checkpoint has {n} tensors, spec wants {}", spec.len());
        }
        let mut tensors = Vec::with_capacity(n);
        for e in spec {
            let name_len = read_u32_le(r)? as usize;
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name)?;
            if name != e.name {
                bail!("checkpoint order mismatch: {} vs {}", name, e.name);
            }
            let count = read_u64_le(r)? as usize;
            if count != e.numel() {
                bail!("param {}: checkpoint numel {count} vs spec {}", e.name, e.numel());
            }
            let data = read_f32_vec(r, count)?;
            tensors.push(Tensor::f32(e.shape.clone(), data));
        }
        ParamSet::new(spec.to_vec(), tensors)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<ParamEntry> {
        vec![
            ParamEntry { name: "a".into(), shape: vec![2, 3], group: "embed".into() },
            ParamEntry { name: "b".into(), shape: vec![4], group: "dense".into() },
        ]
    }

    fn pset() -> ParamSet {
        ParamSet::new(
            spec(),
            vec![
                Tensor::f32(vec![2, 3], (0..6).map(|i| i as f32).collect()),
                Tensor::f32(vec![4], vec![9.0; 4]),
            ],
        )
        .unwrap()
    }

    #[test]
    fn numel_accounting() {
        let p = pset();
        assert_eq!(p.numel(), 10);
        assert_eq!(p.numel_group("embed"), 6);
        assert_eq!(p.numel_group("dense"), 4);
        assert_eq!(p.numel_group("wide"), 0);
    }

    #[test]
    fn zeros_like_matches_shapes() {
        let z = pset().zeros_like();
        assert_eq!(z.tensors[0].shape(), &[2, 3]);
        assert!(z.tensors[0].as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn shape_mismatch_rejected() {
        let bad = ParamSet::new(spec(), vec![Tensor::zeros(&[2, 2]), Tensor::zeros(&[4])]);
        assert!(bad.is_err());
    }

    #[test]
    fn checkpoint_roundtrip() {
        let p = pset();
        let dir = std::env::temp_dir().join(format!("ckpt_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.ckpt");
        p.save(&path).unwrap();
        let back = ParamSet::load(&path, &spec()).unwrap();
        assert_eq!(back.tensors, p.tensors);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn by_name_lookup() {
        let mut p = pset();
        assert!(p.by_name("a").is_some());
        assert!(p.by_name("zz").is_none());
        p.by_name_mut("b").unwrap().scale(2.0).unwrap();
        assert_eq!(p.by_name("b").unwrap().as_f32().unwrap()[0], 18.0);
    }
}
