//! Parameter initialization matching the paper's recipe (Appendix):
//!
//! * dense weights — Kaiming (He) normal, `std = sqrt(2 / fan_in)`;
//!   biases zero.
//! * embeddings — `N(0, sigma)`, with `sigma = 1e-4` for the baseline
//!   runs and `sigma = 1e-2` for CowClip runs (the larger init gives the
//!   norm-proportional clip threshold room to admit gradients early).
//! * wide/LR table — treated as a 1-dim embedding, same sigma.

use super::manifest::ParamEntry;
use super::params::ParamSet;
use crate::tensor::Tensor;
use crate::util::Rng;

/// Initialization hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct InitConfig {
    pub seed: u64,
    /// Embedding (and wide-table) init std.
    pub embed_sigma: f32,
}

impl InitConfig {
    /// Baseline init (paper: sigma = 1e-4).
    pub fn baseline(seed: u64) -> InitConfig {
        InitConfig { seed, embed_sigma: 1e-4 }
    }

    /// Large init used with CowClip (paper: sigma = 1e-2).
    pub fn cowclip(seed: u64) -> InitConfig {
        InitConfig { seed, embed_sigma: 1e-2 }
    }
}

fn is_bias(name: &str) -> bool {
    // Naming convention from python/compile/models: *_b<idx>, *_bout,
    // wide_bias, cross_b<i>, head_b.
    name.ends_with("bias")
        || name
            .rsplit('_')
            .next()
            .map(|last| last.starts_with('b') && !last.starts_with("bw"))
            .unwrap_or(false)
}

/// Initialize a full parameter set for a manifest spec.
pub fn init_params(spec: &[ParamEntry], cfg: &InitConfig) -> ParamSet {
    let mut root = Rng::new(cfg.seed);
    let tensors: Vec<Tensor> = spec
        .iter()
        .enumerate()
        .map(|(i, e)| {
            let mut rng = root.split(i as u64 + 1);
            let n = e.numel();
            let data = match e.group.as_str() {
                "embed" | "wide" => rng.gaussian_vec(n, cfg.embed_sigma),
                _ => {
                    if is_bias(&e.name) {
                        vec![0.0; n]
                    } else {
                        // Kaiming over fan-in: first dim for matrices,
                        // the vector length for 1-D cross weights.
                        let fan_in = if e.shape.len() >= 2 { e.shape[0] } else { e.shape[0] };
                        let std = (2.0 / fan_in as f32).sqrt();
                        rng.gaussian_vec(n, std)
                    }
                }
            };
            Tensor::f32(e.shape.clone(), data)
        })
        .collect();
    ParamSet::new(spec.to_vec(), tensors).expect("init shapes match spec by construction")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(name: &str, shape: Vec<usize>, group: &str) -> ParamEntry {
        ParamEntry { name: name.into(), shape, group: group.into() }
    }

    #[test]
    fn bias_name_detection() {
        for b in ["mlp_b0", "mlp_bout", "wide_bias", "cross_b2", "head_b"] {
            assert!(is_bias(b), "{b} should be a bias");
        }
        for w in ["mlp_w0", "mlp_wout", "embed_table", "cross_w1", "head_w", "cross_W0"] {
            assert!(!is_bias(w), "{w} should not be a bias");
        }
    }

    #[test]
    fn embed_sigma_controls_embedding_scale() {
        let spec = vec![entry("embed_table", vec![1000, 10], "embed")];
        let small = init_params(&spec, &InitConfig::baseline(0));
        let large = init_params(&spec, &InitConfig::cowclip(0));
        let std = |p: &ParamSet| {
            let xs = p.tensors[0].as_f32().unwrap();
            let m: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
            (xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / xs.len() as f32).sqrt()
        };
        assert!((std(&small) / 1e-4 - 1.0).abs() < 0.1);
        assert!((std(&large) / 1e-2 - 1.0).abs() < 0.1);
    }

    #[test]
    fn dense_kaiming_and_zero_bias() {
        let spec = vec![
            entry("mlp_w0", vec![128, 64], "dense"),
            entry("mlp_b0", vec![64], "dense"),
        ];
        let p = init_params(&spec, &InitConfig::baseline(7));
        let w = p.tensors[0].as_f32().unwrap();
        let var: f32 = w.iter().map(|x| x * x).sum::<f32>() / w.len() as f32;
        let want = 2.0 / 128.0;
        assert!((var / want - 1.0).abs() < 0.15, "var {var} want {want}");
        assert!(p.tensors[1].as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let spec = vec![entry("embed_table", vec![50, 4], "embed")];
        let a = init_params(&spec, &InitConfig::baseline(1));
        let b = init_params(&spec, &InitConfig::baseline(1));
        let c = init_params(&spec, &InitConfig::baseline(2));
        assert_eq!(a.tensors, b.tensors);
        assert_ne!(a.tensors, c.tensors);
    }
}
