//! Subcommand implementations for the `cowclip` binary.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use super::args::Args;
use crate::clip::ClipMode;
use crate::coordinator::{
    coordinate_with, dist_worker, DistOptions, Endpoint, Engine, Respawn, TrainConfig, Trainer,
};
use crate::wire::Compression;
use crate::data::dataset::Dataset;
use crate::data::split::{random_split, sequential_split};
use crate::data::stats::{field_stats, infrequent_fraction};
use crate::data::synth::{generate, SynthConfig};
use crate::experiments::{self, ExpContext};
use crate::reference::simd::{self, KernelMode};
use crate::reference::ModelKind;
use crate::runtime::Runtime;
use crate::scaling::presets;
use crate::scaling::rules::ScalingRule;

const USAGE: &str = "\
cowclip — large-batch CTR training (CowClip, AAAI'23 reproduction)

USAGE:
  cowclip data gen   --schema <criteo_synth|avazu_synth> [--n N] [--seed S] --out FILE
  cowclip data stats --path FILE [--batch B]
  cowclip train      [--model deepfm|wd|dcn|dcnv2] [--schema S] [--batch B]
                     [--rule none|sqrt|sqrt_star|linear|n2_lambda|cowclip]
                     [--clip none|global|field|column|adafield|cowclip]
                     [--epochs E] [--n N] [--workers W] [--threads T]
                     [--param-shards P] [--seq-split] [--engine hlo|reference]
                     [--seed S] [--save CKPT] [--resume CKPT]
                     [--ranks R] [--bind SPEC] [--compress none|u16|u8]
                     [--deadline-ms D] [--spawn-workers]
                     [--max-restarts K] [--retransmit-budget B]
                     [--chaos SPEC] [--snapshot-every S]
                     (--threads 0 = one per core [default]; 1 = sequential)
                     (--param-shards 0 = auto [default]; 1 = serial apply;
                      --resume continues step counter + warmup schedule)
                     (--ranks 0 = in-process [default]; R >= 1 runs the
                      multi-process coordinator over framed sockets —
                      --spawn-workers forks the R `cowclip worker` ranks
                      itself; --bind takes unix:PATH or tcp:HOST:PORT,
                      default a temp unix socket; --compress quantizes
                      sparse grads on the wire with error feedback)
                     (fault tolerance: a rank lost mid-step is recovered
                      step-atomically — up to --max-restarts rejoins per
                      rank [default 2, 0 = abort on first loss; requires
                      --compress none]; --retransmit-budget bounds CRC
                      Nack/Resend healing per frame [default 3];
                      --snapshot-every S writes a CCKS snapshot to the
                      --save path every S committed steps;
                      --chaos injects deterministic faults, e.g.
                      'kill:rank=1,step=4;corrupt:rank=0,step=2,times=1;
                      hang:rank=1,step=3,ms=800;seed:7' — kinds kill,
                      hang, corrupt, drop, trunc, delay)
  cowclip worker     --rank R --ranks N --connect SPEC [train flags]
                     [--chaos SPEC] [--max-restarts K]
                     [--retransmit-budget B]
                     (one distributed data-parallel rank: connects to a
                      `train --ranks N` coordinator; data/model flags
                      must match the coordinator's — usually you want
                      `train --spawn-workers` instead of running this
                      by hand)
  cowclip eval       --ckpt FILE --data FILE [--model M] [--batch B]
                     [--engine hlo|reference]
  cowclip serve      --ckpt FILE [--model M] [--schema S] [--quant]
                     [--max-batch N] [--max-delay-us U] [--scoring-threads T]
                     [--max-queue N] [--synthetic] [--duration-ms D]
                     [--qps Q] [--seed S] [--requests FILE.tsv]
                     (micro-batching scorer: synthetic open-loop load for
                      D ms — Q req/s, 0 = max rate — or a TSV of requests;
                      --quant serves u16-quantized tables, ~2x less memory;
                      --max-queue N sheds submits past N pending [0 =
                      unbounded], counted on serve.rejected)
  cowclip inspect    <ckpt> [--model M] [--schema S]
                     (print format/step/per-table sizes of a CCKP/CCKS
                      file; --model+--schema resolve tensor shapes)
  cowclip experiment <id|all|quick> [--n N] [--epochs E] [--seed S] [--out DIR]
  cowclip metrics    (--connect SPEC | --validate-trace FILE |
                      --validate-jsonl FILE) [--timeout-ms T]
                     (one-shot metrics pull from a live `train --ranks
                      --metrics-bind SPEC` coordinator, or CI-style
                      validation of --trace / --metrics-out artifacts)
  cowclip artifacts  check
  cowclip help

Experiments: fig1 fig3 fig4 fig5 fig7_8 table2 table3 table4 table5 table6
             table7 table10 table11 table12 table13 table14 hypers

Kernels: --kernel auto|scalar|avx2|neon (any command; or COWCLIP_KERNEL=...)
         pins the SIMD dispatch tier — 'scalar' forces the portable blocked
         kernels, 'auto' (default) picks the widest tier the host supports.

Observability (train, train --ranks, serve):
         --trace FILE writes a chrome://tracing JSON of step-phase spans;
         --metrics-out FILE [--metrics-interval MS] streams periodic JSONL
         registry snapshots (schema cowclip-metrics-v1); serve --prom dumps
         Prometheus-style text at shutdown; train --ranks --metrics-bind
         SPEC answers live `cowclip metrics --connect SPEC` pulls.
";

/// Entry point used by `main`.
pub fn dispatch(args: Args) -> Result<()> {
    // Pin the SIMD kernel tier before any engine or model is built —
    // the first resolver wins process-wide, so an explicit `--kernel`
    // beats the `COWCLIP_KERNEL` env var read by `simd::active`.
    if let Some(spec) = args.get("kernel") {
        let mode: KernelMode = spec.parse().map_err(anyhow::Error::msg)?;
        let kernels = simd::select(mode);
        println!("simd kernels: {} (requested {spec})", kernels.name);
    }
    match args.positional(0) {
        Some("data") => data_cmd(&args),
        Some("train") => train_cmd(&args),
        Some("worker") => worker_cmd(&args),
        Some("eval") => eval_cmd(&args),
        Some("serve") => serve_cmd(&args),
        Some("inspect") => inspect_cmd(&args),
        Some("experiment") => experiment_cmd(&args),
        Some("metrics") => metrics_cmd(&args),
        Some("artifacts") => artifacts_cmd(&args),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command {other:?}\n\n{USAGE}"),
    }
}

fn artifacts_dir() -> PathBuf {
    PathBuf::from(std::env::var("COWCLIP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()))
}

/// `hlo` when the PJRT backend is compiled in, else the pure-Rust
/// reference engine (the `pjrt` cargo feature is off by default).
fn default_engine() -> &'static str {
    if cfg!(feature = "pjrt") {
        "hlo"
    } else {
        "reference"
    }
}

fn open_runtime() -> Result<Arc<Runtime>> {
    let dir = artifacts_dir();
    Ok(Arc::new(Runtime::new(&dir).with_context(|| {
        format!("opening artifacts at {} — run `make artifacts` first", dir.display())
    })?))
}

/// Observability surface shared by `train`, `train --ranks` and
/// `serve`: `--trace FILE` turns on span tracing for the run and
/// exports a chrome://tracing JSON at the end; `--metrics-out FILE`
/// (with optional `--metrics-interval MS`, default 1000) streams
/// periodic JSONL registry snapshots.
struct ObsSession {
    trace: Option<PathBuf>,
    snapshots: Option<crate::obs::SnapshotWriter>,
}

fn obs_start(args: &Args) -> Result<ObsSession> {
    let trace = args.get("trace").map(PathBuf::from);
    if trace.is_some() {
        crate::obs::reset_spans();
        crate::obs::set_tracing(true);
    }
    // `--metrics-interval` without `--metrics-out` still snapshots, to a
    // default file next to the run.
    let out = match (args.get("metrics-out"), args.has("metrics-interval")) {
        (Some(p), _) => Some(p.to_string()),
        (None, true) => Some("metrics.jsonl".to_string()),
        (None, false) => None,
    };
    let snapshots = match out {
        Some(path) => {
            let interval = Duration::from_millis(args.u64_or("metrics-interval", 1000)?.max(1));
            Some(crate::obs::SnapshotWriter::spawn(Path::new(&path), interval)?)
        }
        None => None,
    };
    Ok(ObsSession { trace, snapshots })
}

impl ObsSession {
    fn finish(self) -> Result<()> {
        if let Some(path) = &self.trace {
            crate::obs::set_tracing(false);
            crate::obs::export_chrome(path)?;
            println!("wrote {}", path.display());
        }
        if let Some(w) = self.snapshots {
            let lines = w.finish()?;
            println!("wrote {lines} metrics snapshot lines");
        }
        Ok(())
    }
}

/// `cowclip metrics`: live one-shot pull over the wire frame protocol
/// (`--connect`), or offline validation of the observability artifacts
/// a traced run produced (`--validate-trace` / `--validate-jsonl`) —
/// the latter is what CI runs against the smoke-test outputs.
fn metrics_cmd(args: &Args) -> Result<()> {
    use crate::util::json::Json;

    let mut did_something = false;
    if let Some(spec) = args.get("connect") {
        let endpoint: Endpoint = spec.parse()?;
        let timeout = Duration::from_millis(args.u64_or("timeout-ms", 5000)?.max(1));
        let body = crate::obs::fetch_metrics(&endpoint, timeout)?;
        println!("{body}");
        did_something = true;
    }
    if let Some(path) = args.get("validate-trace") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {path}"))?;
        let v = Json::parse(&text).with_context(|| format!("{path}: not valid JSON"))?;
        let events = v.get("traceEvents")?.as_arr()?;
        ensure!(!events.is_empty(), "{path}: trace has no events");
        let known: Vec<&str> = crate::obs::Phase::ALL.iter().map(|p| p.name()).collect();
        let mut phases = std::collections::BTreeSet::new();
        for e in events {
            let name = e.get("name")?.as_str()?;
            ensure!(known.contains(&name), "{path}: unknown phase {name:?} in trace");
            ensure!(e.get("ph")?.as_str()? == "X", "{path}: expected complete ('X') events");
            phases.insert(name.to_string());
        }
        println!("{path}: valid chrome trace, {} events, phases {:?}", events.len(), phases);
        did_something = true;
    }
    if let Some(path) = args.get("validate-jsonl") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading snapshots {path}"))?;
        let mut lines = 0usize;
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let v = Json::parse(line).with_context(|| format!("{path}:{}: bad JSON", i + 1))?;
            ensure!(
                v.get("schema")?.as_str()? == "cowclip-metrics-v1",
                "{path}:{}: wrong schema",
                i + 1
            );
            v.get("metrics")?.get("counters")?.as_obj()?;
            lines += 1;
        }
        ensure!(lines > 0, "{path}: no snapshot lines");
        println!("{path}: {lines} valid cowclip-metrics-v1 snapshot lines");
        did_something = true;
    }
    ensure!(
        did_something,
        "usage: cowclip metrics (--connect SPEC | --validate-trace FILE | --validate-jsonl FILE)"
    );
    Ok(())
}

fn data_cmd(args: &Args) -> Result<()> {
    match args.positional(1) {
        Some("gen") => {
            let schema_name = args.str_or("schema", "criteo_synth");
            let schema = crate::data::schema::by_name(&schema_name)
                .with_context(|| format!("unknown schema {schema_name}"))?;
            let cfg = SynthConfig {
                n: args.usize_or("n", 200_000)?,
                seed: args.u64_or("seed", 1234)?,
                ..Default::default()
            };
            let out = args.get("out").context("--out FILE required")?;
            let t0 = std::time::Instant::now();
            let ds = generate(&schema, &cfg);
            ds.save(Path::new(out))?;
            println!(
                "wrote {} rows ({} cat fields, {} dense, ctr {:.3}) to {} in {:.1}s",
                ds.n(),
                schema.n_cat(),
                schema.n_dense,
                ds.ctr(),
                out,
                t0.elapsed().as_secs_f64()
            );
            Ok(())
        }
        Some("stats") => {
            let path = args.get("path").context("--path FILE required")?;
            let ds = Dataset::load(Path::new(path))?;
            let batch = args.usize_or("batch", 512)?;
            println!(
                "{}: {} rows, ctr {:.3}, {} fields, total vocab {}",
                path,
                ds.n(),
                ds.ctr(),
                ds.schema.n_cat(),
                ds.schema.total_vocab()
            );
            println!(
                "infrequent id fraction at batch {batch}: {:.1}%",
                100.0 * infrequent_fraction(&ds, batch)
            );
            for s in field_stats(&ds).iter().take(6) {
                println!(
                    "  field {:>2}: vocab {:>6}  unseen {:>6}  head-10 mass {:>5.1}%",
                    s.field,
                    s.vocab,
                    s.n_unseen,
                    100.0 * s.head_mass(10)
                );
            }
            Ok(())
        }
        _ => bail!("usage: cowclip data <gen|stats> ...\n\n{USAGE}"),
    }
}

/// Everything the `train`-family commands share: the generated + split
/// dataset, the engine, and the resolved [`TrainConfig`]. `worker`
/// builds this from the same flags as the coordinator, so every replica
/// derives bitwise-identical state without any data on the wire.
struct TrainSetup {
    model: ModelKind,
    schema_name: String,
    clip: ClipMode,
    train: Dataset,
    test: Dataset,
    engine: Engine,
    cfg: TrainConfig,
    steps_per_epoch: usize,
}

fn train_setup(args: &Args, workers: usize, verbose: bool) -> Result<TrainSetup> {
    let model: ModelKind = args.str_or("model", "deepfm").parse()?;
    let schema_name = args.str_or("schema", "criteo_synth");
    let batch = args.usize_or("batch", 512)?;
    let rule: ScalingRule = args.str_or("rule", "cowclip").parse()?;
    let clip: ClipMode = args.str_or("clip", "cowclip").parse()?;
    let epochs = args.f64_or("epochs", 3.0)?;
    let n = args.usize_or("n", 100_000)?;
    let threads = args.usize_or("threads", 0)?;
    let param_shards = args.usize_or("param-shards", 0)?;
    let seed = args.u64_or("seed", 1234)?;
    let engine_kind = args.str_or("engine", default_engine());

    let schema = crate::data::schema::by_name(&schema_name)
        .with_context(|| format!("unknown schema {schema_name}"))?;
    if verbose {
        println!("generating {n} rows of {schema_name}...");
    }
    let full = generate(&schema, &SynthConfig { n, seed, ..Default::default() });
    let (train, test) = if args.has("seq-split") {
        sequential_split(&full, 6.0 / 7.0)
    } else {
        let frac = if schema_name == "avazu_synth" { 0.8 } else { 0.9 };
        random_split(&full, frac, seed)
    };

    let engine = match engine_kind.as_str() {
        "hlo" => Engine::hlo(open_runtime()?, model, &schema_name, clip)?,
        "reference" => Engine::reference(model, schema, 10, vec![128, 128, 128], 3, clip),
        other => bail!("unknown engine {other:?} (hlo|reference)"),
    };

    let preset = presets::by_schema(&schema_name).context("no preset")?;
    let use_cowclip_preset = clip == ClipMode::CowClip;
    let base_hypers = if use_cowclip_preset { preset.cowclip } else { preset.baseline };
    let init_sigma = if use_cowclip_preset {
        preset.init_sigma_cowclip
    } else {
        preset.init_sigma_baseline
    };
    let steps_per_epoch = (train.n() / batch).max(1);
    let cfg = TrainConfig {
        batch,
        base_batch: preset.base_batch,
        base_hypers,
        rule,
        epochs,
        workers,
        threads,
        param_shards,
        warmup_steps: if use_cowclip_preset { steps_per_epoch } else { 0 },
        init_sigma,
        seed,
        eval_every_epochs: 1,
        verbose,
    };
    Ok(TrainSetup { model, schema_name, clip, train, test, engine, cfg, steps_per_epoch })
}

fn train_cmd(args: &Args) -> Result<()> {
    let ranks = args.usize_or("ranks", 0)?;
    if ranks > 0 {
        return dist_train_cmd(args, ranks);
    }
    let workers = args.usize_or("workers", 1)?;
    let s = train_setup(args, workers, true)?;
    let TrainSetup { model, schema_name, clip, train, test, engine, cfg, steps_per_epoch } = s;
    println!(
        "training {model} on {schema_name}: batch {} (scale {:.0}x), rule {}, clip {clip}, {} workers on {} threads, {} steps/epoch",
        cfg.batch,
        cfg.scale(),
        cfg.rule,
        workers,
        cfg.threads_for(workers),
        steps_per_epoch
    );
    let mut trainer = Trainer::new(engine, cfg)?;
    println!(
        "apply stage: {} parameter shard{}",
        trainer.store.n_shards(),
        if trainer.store.n_shards() == 1 { " (serial)" } else { "s" }
    );
    if let Some(ckpt) = args.get("resume") {
        trainer.resume_from(Path::new(ckpt))?;
        println!("resumed from {ckpt} at step {}", trainer.step());
    }
    let obs = obs_start(args)?;
    let report = trainer.train(&train, &test)?;
    obs.finish()?;

    println!("\n== result ==");
    println!("steps: {}   wall: {:.1}s", report.steps, report.wall_seconds);
    for (phase, secs) in &report.phase_seconds {
        println!("  {phase:<6} {secs:>8.2}s");
    }
    if report.reduce_stats.workers > 1 {
        println!(
            "  all-reduce: {} merges, {:.1} MiB moved ({:.1} MiB framed on-wire equivalent)",
            report.reduce_stats.rounds,
            report.reduce_stats.bytes_moved as f64 / (1 << 20) as f64,
            report.reduce_stats.wire_bytes as f64 / (1 << 20) as f64
        );
    }
    println!(
        "final test AUC {:.4}%  logloss {:.4}{}",
        report.final_auc * 100.0,
        report.final_logloss,
        if report.diverged { "  [DIVERGED]" } else { "" }
    );
    if let Some(path) = args.get("save") {
        trainer.save_checkpoint(Path::new(path))?;
        println!("checkpoint saved to {path} (params + moments + step {})", trainer.step());
    }
    Ok(())
}

/// Deadline shared by the coordinator's accept loop and every per-frame
/// socket operation (`--deadline-ms`, clamped to at least 1 ms).
fn dist_deadline(args: &Args) -> Result<Duration> {
    Ok(Duration::from_millis(args.u64_or("deadline-ms", 30_000)?.max(1)))
}

/// `train --ranks R`: run the multi-process coordinator over the framed
/// socket transport, optionally forking the R worker ranks itself, and
/// print the wire-traffic report next to the usual quality metrics.
fn dist_train_cmd(args: &Args, ranks: usize) -> Result<()> {
    ensure!(
        !args.has("resume"),
        "--resume is not supported with --ranks: every replica must start from identical state"
    );
    ensure!(
        !args.has("workers"),
        "--workers is implied by --ranks in distributed mode (one worker per rank)"
    );
    let s = train_setup(args, ranks, true)?;
    let compress: Compression = args.str_or("compress", "none").parse()?;
    let deadline = dist_deadline(args)?;
    let default_sock =
        std::env::temp_dir().join(format!("cowclip_dist_{}.sock", std::process::id()));
    let endpoint: Endpoint =
        args.str_or("bind", &format!("unix:{}", default_sock.display())).parse()?;
    let mut opts = DistOptions::new(ranks, endpoint, compress, deadline);
    apply_fault_flags(args, &mut opts)?;
    opts.snapshot_every = args.u64_or("snapshot-every", 0)?;
    if opts.snapshot_every > 0 {
        let path = args
            .get("save")
            .context("--snapshot-every requires --save CKPT (snapshots write there)")?;
        opts.snapshot = Some(PathBuf::from(path));
    }
    println!(
        "distributed training {} on {}: {ranks} ranks at {}, batch {} (scale {:.0}x), rule {}, clip {}, compress {compress}, {} steps/epoch",
        s.model,
        s.schema_name,
        opts.endpoint,
        s.cfg.batch,
        s.cfg.scale(),
        s.cfg.rule,
        s.clip,
        s.steps_per_epoch
    );

    let obs = obs_start(args)?;
    // Baseline for the per-rank wire counters: the registry is
    // process-global, so deltas (not absolutes) describe this run.
    let before = crate::obs::snapshot_metrics();
    if let Some(spec) = args.get("metrics-bind") {
        let ep: Endpoint = spec.parse()?;
        crate::obs::serve_metrics(&ep)?;
        println!("metrics exposition at {ep} (pull with `cowclip metrics --connect {ep}`)");
    }
    let supervisor = if args.has("spawn-workers") {
        Some(WorkerSupervisor::start(args, ranks, &opts)?)
    } else {
        None
    };
    let run = coordinate_with(
        &s.engine,
        &s.cfg,
        &s.train,
        &s.test,
        &opts,
        supervisor.as_ref().map(|sup| sup as &dyn Respawn),
    );
    // Reap the forked ranks before surfacing the coordinator's result so
    // a failed run never leaves orphan processes behind. Only each
    // rank's *last* incarnation must exit cleanly — earlier ones may
    // have died by injected faults and been respawned.
    let worker_failures = match &supervisor {
        Some(sup) => sup.reap(),
        None => Vec::new(),
    };
    let (report, store) = run?;
    ensure!(
        worker_failures.is_empty(),
        "worker processes failed: {}",
        worker_failures.join("; ")
    );

    let mib = |b: u64| b as f64 / (1 << 20) as f64;
    println!("\n== result ==");
    println!("steps: {}   wall: {:.1}s", report.steps, report.wall_seconds);
    println!(
        "  uplink: {} contrib frames, {:.1} MiB raw -> {:.1} MiB on wire ({:.2}x sparse compression)",
        report.stats.rounds,
        mib(report.stats.raw_bytes),
        mib(report.stats.wire_bytes),
        report.stats.compression_ratio()
    );
    println!("  broadcast: {:.1} MiB (lossless totals)", mib(report.stats.bcast_bytes));
    // Per-rank wire traffic from the metrics registry — same counters a
    // live `cowclip metrics --connect` pull reads; their sum matches
    // the uplink/broadcast totals above by construction.
    let after = crate::obs::snapshot_metrics();
    for rank in 0..ranks {
        let delta = |name: &str| after.counter(name).saturating_sub(before.counter(name));
        let rx = delta(&format!("dist.rank{rank}.rx_bytes"));
        let tx = delta(&format!("dist.rank{rank}.tx_bytes"));
        println!("  rank {rank}: {:.1} MiB up, {:.1} MiB down", mib(rx), mib(tx));
    }
    if report.stats.dead_ranks > 0 || report.stats.retransmits > 0 {
        println!(
            "  recovery: {} rank losses, {} rejoins, {} steps recovered, {} frames retransmitted",
            report.stats.dead_ranks,
            report.stats.reconnects,
            report.stats.recovered_steps,
            report.stats.retransmits
        );
    }
    println!(
        "final test AUC {:.4}%  logloss {:.4}",
        report.final_auc * 100.0,
        report.final_logloss
    );
    if let Some(path) = args.get("save") {
        store.save_checkpoint(Path::new(path), report.steps as u64)?;
        println!("checkpoint saved to {path} (params + moments + step {})", report.steps);
    }
    obs.finish()?;
    Ok(())
}

/// Fault-tolerance knobs shared by `train --ranks` and `worker`.
fn apply_fault_flags(args: &Args, opts: &mut DistOptions) -> Result<()> {
    opts.retransmit_budget = args.u64_or("retransmit-budget", 3)? as u32;
    opts.max_restarts = args.u64_or("max-restarts", 2)? as u32;
    if let Some(spec) = args.get("chaos") {
        opts.chaos = Some(spec.parse().context("parsing --chaos")?);
    }
    Ok(())
}

/// Forked `cowclip worker` ranks plus the ability to relaunch one that
/// died mid-run (the coordinator's [`Respawn`] hook). Every spawned
/// child is recorded, and [`WorkerSupervisor::reap`] holds only each
/// rank's *last* incarnation to a clean exit: earlier incarnations may
/// have died on purpose (chaos kills) and been replaced.
struct WorkerSupervisor {
    exe: PathBuf,
    /// `--key value` argv echoed to every rank (data/model flags).
    passthrough: Vec<String>,
    endpoint: String,
    ranks: usize,
    /// Forwarded to the *first* incarnation of each rank only: a
    /// respawn models a fresh post-crash process, so it starts with no
    /// fault schedule (otherwise a `kill` event would fire again and
    /// the run could never converge).
    chaos: Option<String>,
    children: Mutex<Vec<(usize, std::process::Child)>>,
}

impl WorkerSupervisor {
    /// Fork one `cowclip worker` child per rank, echoing the data/model
    /// flags so every replica derives the coordinator's exact state.
    fn start(args: &Args, ranks: usize, opts: &DistOptions) -> Result<WorkerSupervisor> {
        let exe = std::env::current_exe().context("locating the cowclip binary")?;
        let keys = [
            "model",
            "schema",
            "batch",
            "rule",
            "clip",
            "epochs",
            "n",
            "threads",
            "param-shards",
            "seed",
            "engine",
            "deadline-ms",
            "kernel",
            "max-restarts",
            "retransmit-budget",
        ];
        let mut passthrough = Vec::new();
        for key in keys {
            if let Some(v) = args.get(key) {
                passthrough.push(format!("--{key}"));
                passthrough.push(v.to_string());
            }
        }
        if args.has("seq-split") {
            passthrough.push("--seq-split".to_string());
        }
        let sup = WorkerSupervisor {
            exe,
            passthrough,
            endpoint: opts.endpoint.to_string(),
            ranks,
            chaos: args.get("chaos").map(str::to_string),
            children: Mutex::new(Vec::with_capacity(ranks)),
        };
        for rank in 0..ranks {
            sup.spawn_rank(rank, true)?;
        }
        Ok(sup)
    }

    fn spawn_rank(&self, rank: usize, with_chaos: bool) -> Result<()> {
        let mut cmd = std::process::Command::new(&self.exe);
        cmd.arg("worker")
            .args(["--rank", &rank.to_string()])
            .args(["--ranks", &self.ranks.to_string()])
            .args(["--connect", &self.endpoint]);
        for a in &self.passthrough {
            cmd.arg(a);
        }
        if with_chaos {
            if let Some(spec) = &self.chaos {
                cmd.arg("--chaos").arg(spec);
            }
        }
        let child = cmd.spawn().with_context(|| format!("spawning worker rank {rank}"))?;
        self.children.lock().unwrap_or_else(PoisonError::into_inner).push((rank, child));
        Ok(())
    }

    /// Wait for every child ever spawned; returns one message per rank
    /// whose last incarnation did not exit cleanly.
    fn reap(&self) -> Vec<String> {
        let drained = {
            let mut guard = self.children.lock().unwrap_or_else(PoisonError::into_inner);
            std::mem::take(&mut *guard)
        };
        let mut last: std::collections::BTreeMap<usize, Option<String>> =
            std::collections::BTreeMap::new();
        for (rank, mut child) in drained {
            let verdict = match child.wait() {
                Ok(status) if status.success() => None,
                Ok(status) => Some(format!("rank {rank} exited with {status}")),
                Err(e) => Some(format!("rank {rank} not reaped: {e}")),
            };
            last.insert(rank, verdict);
        }
        last.into_values().flatten().collect()
    }
}

impl Respawn for WorkerSupervisor {
    fn respawn(&self, rank: usize) -> Result<()> {
        // Post-crash processes start clean: no chaos schedule.
        self.spawn_rank(rank, false)
    }
}

/// One distributed data-parallel rank: rebuild the coordinator's replica
/// state from the same flags, connect, and run the socket step loop.
fn worker_cmd(args: &Args) -> Result<()> {
    let rank: usize = args
        .get("rank")
        .context("--rank R required")?
        .parse()
        .context("--rank must be an integer")?;
    let ranks = args.usize_or("ranks", 0)?;
    ensure!(ranks >= 1, "--ranks N required");
    let endpoint: Endpoint = args.get("connect").context("--connect SPEC required")?.parse()?;
    let deadline = dist_deadline(args)?;
    let s = train_setup(args, ranks, false)?;
    // The coordinator's Welcome dictates the wire compression; the
    // worker-side field is never consulted.
    let mut opts = DistOptions::new(ranks, endpoint, Compression::None, deadline);
    apply_fault_flags(args, &mut opts)?;
    dist_worker(&s.engine, &s.cfg, &s.train, rank, &opts)
}

/// Evaluate a checkpoint on a `.ctr` dataset file: AUC, logloss, and
/// calibration (Brier / ECE) — streamed from disk. Accepts both the
/// PR-1 `CCKP` params format and the full `CCKS` training checkpoint.
fn eval_cmd(args: &Args) -> Result<()> {
    use crate::data::stream::StreamReader;
    use crate::metrics::{brier_from_logits, ece_from_logits, EvalAccumulator};
    use crate::model::store::ParamStore;

    let ckpt = args.get("ckpt").context("--ckpt FILE required")?;
    let data = args.get("data").context("--data FILE required")?;
    let model: ModelKind = args.str_or("model", "deepfm").parse()?;
    let reader = StreamReader::open(Path::new(data))?;
    let schema_name = reader.schema.name.clone();

    let engine = match args.str_or("engine", default_engine()).as_str() {
        "hlo" => Engine::hlo(open_runtime()?, model, &schema_name, ClipMode::CowClip)?,
        "reference" => {
            let schema = crate::data::schema::by_name(&schema_name)
                .with_context(|| format!("unknown schema {schema_name}"))?;
            // same architecture constants as `train --engine reference`
            Engine::reference(model, schema, 10, vec![128, 128, 128], 3, ClipMode::CowClip)
        }
        other => bail!("unknown engine {other:?} (hlo|reference)"),
    };
    let params = ParamStore::load_params(Path::new(ckpt), &engine.spec())?;
    let eval_batch = engine.eval_batch().unwrap_or(1024);

    let mut acc = EvalAccumulator::new();
    let mut logits_all: Vec<f32> = Vec::with_capacity(reader.n);
    let mut labels_all: Vec<u8> = Vec::with_capacity(reader.n);
    let mut lo = 0;
    while lo < reader.n {
        let hi = (lo + eval_batch).min(reader.n);
        let mut b = reader.read_rows(lo, hi)?;
        // pad up to the artifact batch by repeating the last row
        if b.batch_size() < eval_batch {
            let valid = b.batch_size();
            let mut idx: Vec<usize> = (lo..hi).collect();
            while idx.len() < eval_batch {
                idx.push(hi - 1);
            }
            b = reader.read_rows(lo, hi)?; // reread; then extend manually
            let extra = reader.read_rows(hi - 1, hi)?;
            let mut cat = b.x_cat.as_i32()?.to_vec();
            let mut dense = b.x_dense.as_f32()?.to_vec();
            let mut y = b.y.as_f32()?.to_vec();
            while y.len() < eval_batch {
                cat.extend_from_slice(extra.x_cat.as_i32()?);
                dense.extend_from_slice(extra.x_dense.as_f32()?);
                y.push(extra.y.as_f32()?[0]);
            }
            b = crate::data::batcher::Batch::new(
                crate::tensor::Tensor::i32(vec![eval_batch, reader.schema.n_cat()], cat),
                crate::tensor::Tensor::f32(vec![eval_batch, reader.schema.n_dense], dense),
                crate::tensor::Tensor::f32(vec![eval_batch], y),
                valid,
            );
        }
        let logits = engine.fwd(&params, &b)?;
        acc.push(&logits, b.y.as_f32()?, b.valid);
        logits_all.extend_from_slice(&logits[..b.valid]);
        labels_all.extend(b.y.as_f32()?[..b.valid].iter().map(|&v| v as u8));
        lo = hi;
    }
    println!("{data}: {} rows evaluated with {model} from {ckpt}", acc.n());
    println!("  AUC      {:.4}%", acc.auc() * 100.0);
    println!("  logloss  {:.4}", acc.logloss());
    println!("  Brier    {:.4}", brier_from_logits(&logits_all, &labels_all));
    println!("  ECE(10)  {:.4}", ece_from_logits(&logits_all, &labels_all, 10));
    Ok(())
}

/// Serve a checkpoint through the micro-batching scorer and drive it
/// with either a synthetic open-loop load (the default: `RowSampler`
/// draws requests from the training synthesizer's Zipf id model) or a
/// TSV of requests. Prints QPS, batch-coalescing and latency stats.
fn serve_cmd(args: &Args) -> Result<()> {
    use std::collections::VecDeque;
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    use crate::data::synth::RowSampler;
    use crate::reference::ReferenceModel;
    use crate::serve::{read_requests_tsv, score_all, Request, ServeConfig, ServeModel, Server};

    let ckpt = args.get("ckpt").context("--ckpt FILE required")?;
    let model: ModelKind = args.str_or("model", "deepfm").parse()?;
    let schema_name = args.str_or("schema", "criteo_synth");
    let schema = crate::data::schema::by_name(&schema_name)
        .with_context(|| format!("unknown schema {schema_name}"))?;
    let quant = args.has("quant");
    // same architecture constants as `train --engine reference`
    let reference = ReferenceModel::new(model, schema.clone(), 10, vec![128, 128, 128], 3);
    let frozen = Arc::new(ServeModel::load(Path::new(ckpt), reference, quant)?);
    let mib = |b: usize| b as f64 / (1 << 20) as f64;
    println!(
        "loaded {model} from {ckpt}: {:.1} MiB resident ({:.1} MiB as f32{})",
        mib(frozen.serving_bytes()),
        mib(frozen.f32_bytes()),
        match frozen.quant_error_bound() {
            Some(b) => format!(", u16-quantized tables, per-field bound <= {b:.2e}"),
            None => String::new(),
        }
    );

    let cfg = ServeConfig {
        max_batch: args.usize_or("max-batch", 64)?.max(1),
        max_delay: Duration::from_micros(args.u64_or("max-delay-us", 2000)?),
        threads: args.usize_or("scoring-threads", 2)?.max(1),
        max_queue: args.usize_or("max-queue", 0)?,
    };
    println!(
        "serving: max batch {}, deadline {} us, {} scoring threads, queue bound {}",
        cfg.max_batch,
        cfg.max_delay.as_micros(),
        cfg.threads,
        if cfg.max_queue == 0 { "off".to_string() } else { cfg.max_queue.to_string() }
    );
    let obs = obs_start(args)?;
    let server = Server::start(Arc::clone(&frozen), cfg);
    let client = server.client();

    if let Some(tsv) = args.get("requests") {
        let reqs = read_requests_tsv(Path::new(tsv), frozen.schema())?;
        println!("scoring {} requests from {tsv}...", reqs.len());
        let scored = score_all(&client, reqs)?;
        let mean_p: f64 =
            scored.iter().map(|s| s.prob as f64).sum::<f64>() / scored.len().max(1) as f64;
        println!("mean p(click) {mean_p:.4}");
    } else {
        let duration = Duration::from_millis(args.u64_or("duration-ms", 2000)?);
        let target_qps = args.f64_or("qps", 0.0)?;
        let seed = args.u64_or("seed", 1234)?;
        let mut sampler = RowSampler::new(
            &schema,
            &crate::data::synth::SynthConfig { seed, ..Default::default() },
        );
        println!(
            "synthetic open-loop load for {} ms ({})...",
            duration.as_millis(),
            if target_qps > 0.0 { format!("{target_qps:.0} req/s") } else { "max rate".into() }
        );
        let t0 = Instant::now();
        let mut offered = crate::metrics::QpsMeter::new();
        let mut pending = VecDeque::new();
        while t0.elapsed() < duration {
            let (cat, dense) = sampler.next_row();
            pending.push_back(client.submit(Request { id: offered.count(), cat, dense })?);
            offered.hit(1);
            if target_qps > 0.0 {
                // open loop: pace arrivals off the wall clock, not responses
                let due = Duration::from_secs_f64(offered.count() as f64 / target_qps);
                if let Some(sleep) = due.checked_sub(t0.elapsed()) {
                    std::thread::sleep(sleep);
                }
            }
            // bound driver memory without closing the loop on every reply
            while pending.len() > 50_000 {
                if let Some(rx) = pending.pop_front() {
                    let _ = rx.recv();
                }
            }
        }
        let offered_qps = offered.qps();
        for rx in pending {
            let _ = rx.recv();
        }
        println!("offered load: {} requests at {:.0} req/s", offered.count(), offered_qps);
    }

    let stats = server.shutdown()?;
    let (p50, p90, p99, mean) = stats.latency.summary();
    println!("\n== serving report ==");
    println!("  requests      {:>10}", stats.requests);
    println!("  wall          {:>10.2} s", stats.wall.as_secs_f64());
    println!("  QPS           {:>10.0}", stats.qps());
    println!("  micro-batches {:>10}   (mean size {:.1})", stats.batches, stats.mean_batch());
    println!("  latency ms    p50 {p50:>8.3}   p90 {p90:>8.3}   p99 {p99:>8.3}   mean {mean:>8.3}");
    if args.has("prom") {
        println!("\n== metrics (prometheus text) ==");
        print!("{}", crate::obs::prometheus_text());
    }
    obs.finish()?;
    Ok(())
}

/// Sanity-check a checkpoint artifact: format, step counter, per-table
/// sizes. With `--model`/`--schema` the spec resolves tensor shapes.
fn inspect_cmd(args: &Args) -> Result<()> {
    use crate::model::inspect_checkpoint;
    use crate::reference::step::build_spec;

    let path = args
        .positional(1)
        .context("usage: cowclip inspect <ckpt> [--model M] [--schema S]")?;
    let info = inspect_checkpoint(Path::new(path))?;
    println!(
        "{path}: {} checkpoint{}, optimizer step {}",
        info.format,
        if info.format == "CCKS" { format!(" v{}", info.version) } else { String::new() },
        info.step
    );
    println!(
        "  state: {}",
        if info.has_moments {
            "params + Adam moments + lazy-Adam row clocks (resumable)"
        } else {
            "params only (serving/eval)"
        }
    );

    // optional shape resolution against the reference spec
    let spec = if args.has("model") || args.has("schema") {
        let model: ModelKind = args.str_or("model", "deepfm").parse()?;
        let schema_name = args.str_or("schema", "criteo_synth");
        let schema = crate::data::schema::by_name(&schema_name)
            .with_context(|| format!("unknown schema {schema_name}"))?;
        Some(build_spec(model, &schema, 10, &[128, 128, 128], 3))
    } else {
        None
    };

    for e in &info.params {
        let shape = spec
            .as_ref()
            .and_then(|s| s.iter().find(|se| se.name == e.name))
            .filter(|se| se.numel() as u64 == e.numel)
            .map(|se| format!("{:?}", se.shape))
            .unwrap_or_else(|| "-".into());
        println!(
            "  {:<16} {:>12} params {:>12} bytes  shape {}",
            e.name,
            e.numel,
            e.numel * 4,
            shape
        );
    }
    println!(
        "  total: {} tensors, {} params, {:.2} MiB (f32)",
        info.params.len(),
        info.total_numel(),
        info.total_bytes() as f64 / (1 << 20) as f64
    );
    if let Some(spec) = &spec {
        let named: std::collections::HashSet<&str> =
            info.params.iter().map(|e| e.name.as_str()).collect();
        let missing: Vec<&str> = spec
            .iter()
            .filter(|se| !named.contains(se.name.as_str()))
            .map(|se| se.name.as_str())
            .collect();
        if missing.is_empty() {
            println!("  spec check: all expected tensors present");
        } else {
            println!("  spec check: MISSING {missing:?}");
        }
    }
    Ok(())
}

fn experiment_cmd(args: &Args) -> Result<()> {
    let which = args.positional(1).context("experiment id required (or 'all'/'quick')")?;
    let n = args.usize_or("n", 40_000)?;
    let epochs = args.f64_or("epochs", 2.0)?;
    let seed = args.u64_or("seed", 1234)?;
    let out_dir = PathBuf::from(args.str_or("out", "results"));
    let runtime = if args.str_or("engine", default_engine()) == "hlo" {
        Some(open_runtime()?)
    } else {
        None
    };
    let ctx = ExpContext::new(runtime, n, epochs, seed);

    let ids: Vec<&str> = match which {
        "all" => experiments::ALL_IDS.to_vec(),
        "quick" => experiments::QUICK_IDS.to_vec(),
        one => vec![one],
    };
    for id in ids {
        let t0 = std::time::Instant::now();
        println!("=== running {id} (n={n}, epochs={epochs}) ===");
        let report = experiments::run(id, &ctx)?;
        report.emit(&out_dir)?;
        println!("=== {id} done in {:.1}s ===\n", t0.elapsed().as_secs_f64());
    }
    Ok(())
}

fn artifacts_cmd(args: &Args) -> Result<()> {
    match args.positional(1) {
        Some("check") => {
            let rt = open_runtime()?;
            let m = rt.manifest();
            println!(
                "manifest v{} at {}: {} artifacts, {} schemas, platform {}",
                m.version,
                m.dir.display(),
                m.artifacts.len(),
                m.schema_names().len(),
                rt.platform()
            );
            // compile everything to prove the HLO text parses + compiles
            let mut compiled = 0;
            for a in m.artifacts.clone() {
                rt.load(&a)?;
                compiled += 1;
            }
            println!("compiled {compiled}/{} programs OK", m.artifacts.len());
            // schema drift check against rust presets
            for name in ["criteo_synth", "avazu_synth"] {
                let ours = crate::data::schema::by_name(name)
                    .with_context(|| format!("unknown preset schema {name}"))?;
                let theirs = m.schema(name)?;
                if ours != theirs {
                    bail!("schema drift for {name}");
                }
            }
            println!("schemas match rust presets");
            Ok(())
        }
        _ => bail!("usage: cowclip artifacts check"),
    }
}
