//! Tiny flag parser: positional words + `--key value` / `--flag` pairs.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positionals: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse from raw arguments (without argv[0]). A `--key` followed by
    /// another `--...` or end-of-line is a boolean flag.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare '--' not supported");
                }
                let is_flag = it
                    .peek()
                    .map(|n| n.starts_with("--"))
                    .unwrap_or(true);
                let value = if is_flag {
                    "true".to_string()
                } else {
                    it.next().with_context(|| format!("--{key} is missing its value"))?
                };
                if args.flags.insert(key.to_string(), value).is_some() {
                    bail!("duplicate flag --{key}");
                }
            } else {
                args.positionals.push(a);
            }
        }
        Ok(args)
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?} is not an integer")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?} is not a number")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?} is not an integer")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn positionals_and_flags() {
        let a = parse("experiment table4 --n 5000 --quick --epochs 1.5");
        assert_eq!(a.positional(0), Some("experiment"));
        assert_eq!(a.positional(1), Some("table4"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 5000);
        assert!(a.has("quick"));
        assert_eq!(a.f64_or("epochs", 0.0).unwrap(), 1.5);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse("train --verbose");
        assert!(a.has("verbose"));
    }

    #[test]
    fn duplicate_flag_rejected() {
        assert!(Args::parse(["--a", "1", "--a", "2"].map(String::from)).is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("--n abc");
        assert!(a.usize_or("n", 0).is_err());
    }
}
