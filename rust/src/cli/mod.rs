//! Command-line interface (hand-rolled: the offline build has no clap).

pub mod args;
pub mod commands;

pub use args::Args;
pub use commands::dispatch;
