//! Exact AUC (area under the ROC curve) via the Mann–Whitney statistic.
//!
//! AUC = P(score_pos > score_neg) + 0.5 * P(tie), computed in
//! O(n log n) by rank-summing with proper tie handling — the paper's
//! headline metric, where a 0.1% delta is considered significant, so an
//! approximation is not acceptable.

/// Exact AUC. `scores` may be logits or probabilities (rank-invariant).
/// Returns 0.5 when one class is absent.
pub fn auc(scores: &[f32], labels: &[u8]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n_pos = labels.iter().filter(|&&y| y == 1).count();
    let n_neg = labels.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }

    let mut idx: Vec<usize> = (0..scores.len()).collect();
    // total_cmp: NaN scores (e.g. from a diverged large-batch run) sort
    // deterministically instead of panicking mid-eval
    idx.sort_unstable_by(|&a, &b| scores[a].total_cmp(&scores[b]));

    // average ranks over tie groups
    let mut rank_sum_pos = 0.0f64;
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        // ranks are 1-based; tie group [i..=j] shares the average rank
        let avg_rank = (i + j + 2) as f64 / 2.0;
        for &k in &idx[i..=j] {
            if labels[k] == 1 {
                rank_sum_pos += avg_rank;
            }
        }
        i = j + 1;
    }

    let u = rank_sum_pos - (n_pos * (n_pos + 1)) as f64 / 2.0;
    u / (n_pos as f64 * n_neg as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_separation() {
        let scores = [0.1, 0.2, 0.8, 0.9];
        let labels = [0, 0, 1, 1];
        assert!((auc(&scores, &labels) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn perfect_inversion() {
        let scores = [0.9, 0.8, 0.1, 0.2];
        let labels = [0, 0, 1, 1];
        assert!(auc(&scores, &labels).abs() < 1e-12);
    }

    #[test]
    fn random_is_half() {
        // deterministic interleaving: alternate labels on equal spacing
        let scores: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let labels: Vec<u8> = (0..1000).map(|i| (i % 2) as u8).collect();
        let a = auc(&scores, &labels);
        assert!((a - 0.5).abs() < 0.01, "auc {a}");
    }

    #[test]
    fn ties_count_half() {
        let scores = [0.5, 0.5];
        let labels = [0, 1];
        assert!((auc(&scores, &labels) - 0.5).abs() < 1e-12);
        // one tie + one correct pair: (1 + 0.5)/2
        let scores = [0.5, 0.5, 0.9];
        let labels = [0, 1, 1];
        assert!((auc(&scores, &labels) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn degenerate_single_class() {
        assert_eq!(auc(&[0.1, 0.9], &[1, 1]), 0.5);
        assert_eq!(auc(&[0.1, 0.9], &[0, 0]), 0.5);
    }

    #[test]
    fn nan_scores_do_not_panic() {
        // regression: a single NaN logit from a diverged run used to
        // panic in partial_cmp().unwrap() mid-eval
        let scores = [0.2f32, f32::NAN, 0.8, 0.5, f32::NAN];
        let labels = [0u8, 1, 1, 0, 0];
        let a = auc(&scores, &labels);
        assert!((0.0..=1.0).contains(&a), "auc {a}");
        // all-NaN input is also survivable
        let a = auc(&[f32::NAN, f32::NAN], &[0, 1]);
        assert!((0.0..=1.0).contains(&a));
    }

    #[test]
    fn matches_bruteforce_pair_count() {
        use crate::util::Rng;
        let mut rng = Rng::new(5);
        let scores: Vec<f32> = (0..300).map(|_| (rng.below(50)) as f32 / 10.0).collect();
        let labels: Vec<u8> = (0..300).map(|_| rng.bernoulli(0.3) as u8).collect();
        // brute force
        let mut wins = 0.0f64;
        let mut total = 0.0f64;
        for i in 0..scores.len() {
            for j in 0..scores.len() {
                if labels[i] == 1 && labels[j] == 0 {
                    total += 1.0;
                    if scores[i] > scores[j] {
                        wins += 1.0;
                    } else if scores[i] == scores[j] {
                        wins += 0.5;
                    }
                }
            }
        }
        let brute = wins / total;
        assert!((auc(&scores, &labels) - brute).abs() < 1e-10);
    }

    #[test]
    fn rank_invariance() {
        let scores = [0.1f32, 0.4, 0.35, 0.8];
        let labels = [0u8, 0, 1, 1];
        let logits: Vec<f32> = scores.iter().map(|&p| (p / (1.0 - p)).ln()).collect();
        assert!((auc(&scores, &labels) - auc(&logits, &labels)).abs() < 1e-12);
    }
}
