//! Probability-calibration metrics.
//!
//! CTR systems bid money on predicted probabilities, so beyond ranking
//! (AUC) the *calibration* of p̂ matters: the paper's L2/overfitting
//! discussion is ultimately about keeping predictions calibrated at
//! large batch. We report the standard pair:
//!
//! * **Brier score** — mean squared error of probabilities.
//! * **ECE** (expected calibration error) — confidence-binned |p̂ − ȳ|,
//!   weighted by bin occupancy.

use super::logloss::sigmoid;

/// Brier score from logits: `mean((sigmoid(z) - y)^2)`.
pub fn brier_from_logits(logits: &[f32], labels: &[u8]) -> f64 {
    assert_eq!(logits.len(), labels.len());
    assert!(!logits.is_empty());
    logits
        .iter()
        .zip(labels)
        .map(|(&z, &y)| {
            let d = sigmoid(z) as f64 - y as f64;
            d * d
        })
        .sum::<f64>()
        / logits.len() as f64
}

/// Expected calibration error over `bins` equal-width probability bins.
pub fn ece_from_logits(logits: &[f32], labels: &[u8], bins: usize) -> f64 {
    assert_eq!(logits.len(), labels.len());
    assert!(bins > 0 && !logits.is_empty());
    let mut sum_p = vec![0.0f64; bins];
    let mut sum_y = vec![0.0f64; bins];
    let mut count = vec![0usize; bins];
    for (&z, &y) in logits.iter().zip(labels) {
        let p = sigmoid(z) as f64;
        let b = ((p * bins as f64) as usize).min(bins - 1);
        sum_p[b] += p;
        sum_y[b] += y as f64;
        count[b] += 1;
    }
    let n = logits.len() as f64;
    (0..bins)
        .filter(|&b| count[b] > 0)
        .map(|b| {
            let c = count[b] as f64;
            (c / n) * ((sum_p[b] / c) - (sum_y[b] / c)).abs()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn brier_perfect_and_worst() {
        // confident-correct ~ 0; confident-wrong ~ 1
        assert!(brier_from_logits(&[20.0, -20.0], &[1, 0]) < 1e-6);
        assert!(brier_from_logits(&[20.0, -20.0], &[0, 1]) > 0.99);
    }

    #[test]
    fn brier_at_half_is_quarter() {
        let b = brier_from_logits(&[0.0, 0.0], &[0, 1]);
        assert!((b - 0.25).abs() < 1e-9);
    }

    #[test]
    fn ece_zero_for_perfectly_calibrated_bins() {
        // p = 0.5 predictions with a 50% positive rate -> ECE ~ 0
        let logits = vec![0.0f32; 1000];
        let labels: Vec<u8> = (0..1000).map(|i| (i % 2) as u8).collect();
        assert!(ece_from_logits(&logits, &labels, 10) < 1e-9);
    }

    #[test]
    fn ece_detects_systematic_overconfidence() {
        // predict 0.9 while the true rate is 0.5
        let logits = vec![2.1972246f32; 2000]; // sigmoid ~ 0.9
        let labels: Vec<u8> = (0..2000).map(|i| (i % 2) as u8).collect();
        let ece = ece_from_logits(&logits, &labels, 10);
        assert!((ece - 0.4).abs() < 0.01, "ece {ece}");
    }

    #[test]
    fn ece_bin_edges_do_not_panic() {
        let logits = [f32::MAX.ln(), -50.0, 0.0];
        let labels = [1u8, 0, 1];
        let e = ece_from_logits(&logits, &labels, 4);
        assert!(e.is_finite());
    }
}
