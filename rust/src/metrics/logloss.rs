//! Logistic loss (the paper's secondary metric).

/// Numerically stable sigmoid.
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Mean logloss from probabilities (clipped away from 0/1).
pub fn logloss(probs: &[f32], labels: &[u8]) -> f64 {
    assert_eq!(probs.len(), labels.len());
    assert!(!probs.is_empty());
    let mut total = 0.0f64;
    for (&p, &y) in probs.iter().zip(labels) {
        let p = (p as f64).clamp(1e-7, 1.0 - 1e-7);
        total -= if y == 1 { p.ln() } else { (1.0 - p).ln() };
    }
    total / probs.len() as f64
}

/// Mean logloss computed stably from logits:
/// `max(z,0) - z*y + log(1+exp(-|z|))`.
pub fn logloss_from_logits(logits: &[f32], labels: &[u8]) -> f64 {
    assert_eq!(logits.len(), labels.len());
    assert!(!logits.is_empty());
    let mut total = 0.0f64;
    for (&z, &y) in logits.iter().zip(labels) {
        let z = z as f64;
        total += z.max(0.0) - z * y as f64 + (-z.abs()).exp().ln_1p();
    }
    total / logits.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_symmetry_and_range() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!((sigmoid(3.0) + sigmoid(-3.0) - 1.0).abs() < 1e-6);
        assert!(sigmoid(-100.0) >= 0.0 && sigmoid(100.0) <= 1.0);
    }

    #[test]
    fn known_value() {
        // p=0.5 everywhere -> ln 2
        let ll = logloss(&[0.5, 0.5], &[0, 1]);
        assert!((ll - std::f64::consts::LN_2).abs() < 1e-9);
    }

    #[test]
    fn logits_and_probs_agree() {
        let logits = [-2.0f32, -0.5, 0.0, 1.5, 3.0];
        let labels = [0u8, 1, 0, 1, 1];
        let probs: Vec<f32> = logits.iter().map(|&z| sigmoid(z)).collect();
        let a = logloss(&probs, &labels);
        let b = logloss_from_logits(&logits, &labels);
        assert!((a - b).abs() < 1e-6, "{a} vs {b}");
    }

    #[test]
    fn confident_wrong_is_expensive() {
        let good = logloss(&[0.9], &[1]);
        let bad = logloss(&[0.1], &[1]);
        assert!(bad > good * 5.0);
    }

    #[test]
    fn extreme_logits_are_finite() {
        let ll = logloss_from_logits(&[1e4, -1e4], &[1, 0]);
        assert!(ll.is_finite());
        assert!(ll < 1e-3);
    }
}
