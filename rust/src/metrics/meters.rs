//! Streaming metric accumulators used by the trainer, the evaluators and
//! the online serving tier (latency histogram + QPS meter).

use std::time::Instant;

use super::{auc, logloss_from_logits};

/// Running mean of per-step training loss.
#[derive(Clone, Debug, Default)]
pub struct LossMeter {
    sum: f64,
    n: usize,
}

impl LossMeter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn update(&mut self, loss: f64) {
        self.sum += loss;
        self.n += 1;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn reset(&mut self) {
        self.sum = 0.0;
        self.n = 0;
    }
}

/// Collects (logit, label) pairs across eval batches, then computes AUC
/// and logloss in one pass. Padding rows are dropped via `valid`.
#[derive(Clone, Debug, Default)]
pub struct EvalAccumulator {
    logits: Vec<f32>,
    labels: Vec<u8>,
}

impl EvalAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append the first `valid` entries of a batch's outputs.
    pub fn push(&mut self, logits: &[f32], labels: &[f32], valid: usize) {
        assert!(valid <= logits.len() && valid <= labels.len());
        self.logits.extend_from_slice(&logits[..valid]);
        self.labels.extend(labels[..valid].iter().map(|&y| y as u8));
    }

    pub fn n(&self) -> usize {
        self.labels.len()
    }

    pub fn auc(&self) -> f64 {
        auc(&self.logits, &self.labels)
    }

    pub fn logloss(&self) -> f64 {
        logloss_from_logits(&self.logits, &self.labels)
    }
}

/// Number of latency buckets (fixed so histograms merge trivially).
const LAT_BUCKETS: usize = 64;
/// First bucket upper bound in milliseconds (1 µs).
const LAT_BASE_MS: f64 = 1e-3;
/// Geometric bucket growth; 64 buckets cover ~1 µs to ~15 s.
const LAT_RATIO: f64 = 1.3;

/// Fixed-bucket latency histogram with log-spaced bounds.
///
/// Bucket `i` covers `(base·r^(i-1), base·r^i]` milliseconds, with the
/// last bucket absorbing everything larger, so recording is O(1), the
/// memory footprint is constant, and two histograms (e.g. per scoring
/// thread) merge by adding counts. Percentiles interpolate linearly
/// inside the winning bucket and are clamped to the observed
/// `[min, max]`, which makes the empty (0.0), single-sample and
/// all-equal cases exact.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    counts: [u64; LAT_BUCKETS],
    n: u64,
    sum_ms: f64,
    min_ms: f64,
    max_ms: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            counts: [0; LAT_BUCKETS],
            n: 0,
            sum_ms: 0.0,
            min_ms: f64::INFINITY,
            max_ms: 0.0,
        }
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Upper bound of bucket `i` in milliseconds.
    fn bound(i: usize) -> f64 {
        LAT_BASE_MS * LAT_RATIO.powi(i as i32)
    }

    fn bucket_of(ms: f64) -> usize {
        if ms <= LAT_BASE_MS {
            return 0;
        }
        let i = ((ms / LAT_BASE_MS).ln() / LAT_RATIO.ln()).ceil() as usize;
        i.min(LAT_BUCKETS - 1)
    }

    /// Record one latency sample in milliseconds (negatives clamp to 0).
    pub fn record(&mut self, ms: f64) {
        let ms = ms.max(0.0);
        self.counts[Self::bucket_of(ms)] += 1;
        self.n += 1;
        self.sum_ms += ms;
        self.min_ms = self.min_ms.min(ms);
        self.max_ms = self.max_ms.max(ms);
    }

    /// Fold another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.n += other.n;
        self.sum_ms += other.sum_ms;
        self.min_ms = self.min_ms.min(other.min_ms);
        self.max_ms = self.max_ms.max(other.max_ms);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean_ms(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum_ms / self.n as f64
        }
    }

    pub fn max_ms(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max_ms
        }
    }

    /// Percentile `p` in `[0, 100]` in milliseconds (0.0 when empty).
    /// Resolution is one bucket (~±15%); exact for single-sample and
    /// all-equal inputs thanks to the `[min, max]` clamp.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (p.clamp(0.0, 100.0) / 100.0) * self.n as f64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let next = seen + c;
            if (next as f64) >= target {
                let lo = if i == 0 { 0.0 } else { Self::bound(i - 1) };
                // the last bucket is unbounded above: close it with the
                // observed max so p100 reports the true extreme
                let hi = if i == LAT_BUCKETS - 1 { self.max_ms } else { Self::bound(i) };
                let frac = ((target - seen as f64) / c as f64).clamp(0.0, 1.0);
                return (lo + frac * (hi - lo)).clamp(self.min_ms, self.max_ms);
            }
            seen = next;
        }
        self.max_ms
    }

    /// `(p50, p90, p99, mean)` in milliseconds — the serving report row.
    pub fn summary(&self) -> (f64, f64, f64, f64) {
        (self.percentile(50.0), self.percentile(90.0), self.percentile(99.0), self.mean_ms())
    }
}

/// Wall-clock throughput meter: count events, read events/second.
#[derive(Clone, Debug)]
pub struct QpsMeter {
    started: Instant,
    n: u64,
}

impl Default for QpsMeter {
    fn default() -> Self {
        Self::new()
    }
}

impl QpsMeter {
    pub fn new() -> Self {
        QpsMeter { started: Instant::now(), n: 0 }
    }

    /// Count `k` completed events.
    pub fn hit(&mut self, k: u64) {
        self.n += k;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Events per second since construction.
    pub fn qps(&self) -> f64 {
        let secs = self.elapsed_secs();
        if secs <= 0.0 {
            0.0
        } else {
            self.n as f64 / secs
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_meter_mean() {
        let mut m = LossMeter::new();
        assert_eq!(m.mean(), 0.0);
        m.update(1.0);
        m.update(3.0);
        assert_eq!(m.mean(), 2.0);
        assert_eq!(m.count(), 2);
        m.reset();
        assert_eq!(m.count(), 0);
    }

    #[test]
    fn eval_accumulator_drops_padding() {
        let mut acc = EvalAccumulator::new();
        acc.push(&[2.0, -1.0, 9.9], &[1.0, 0.0, 1.0], 2); // last row is padding
        acc.push(&[0.5], &[1.0], 1);
        assert_eq!(acc.n(), 3);
        assert!((acc.auc() - 1.0).abs() < 1e-12);
        assert!(acc.logloss() > 0.0);
    }

    #[test]
    fn latency_histogram_empty_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_ms(), 0.0);
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.percentile(99.0), 0.0);
        assert_eq!(h.max_ms(), 0.0);
    }

    #[test]
    fn latency_histogram_single_sample_is_exact() {
        let mut h = LatencyHistogram::new();
        h.record(3.7);
        for p in [0.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(h.percentile(p), 3.7, "p{p}");
        }
        assert!((h.mean_ms() - 3.7).abs() < 1e-12);
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn latency_histogram_all_equal_is_exact() {
        let mut h = LatencyHistogram::new();
        for _ in 0..1000 {
            h.record(0.25);
        }
        assert_eq!(h.percentile(50.0), 0.25);
        assert_eq!(h.percentile(99.0), 0.25);
        assert!((h.mean_ms() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn latency_histogram_percentiles_are_monotone_and_bucket_accurate() {
        let mut h = LatencyHistogram::new();
        // 1..=100 ms uniformly
        for i in 1..=100 {
            h.record(i as f64);
        }
        let (p50, p90, p99, mean) = h.summary();
        assert!(p50 <= p90 && p90 <= p99, "({p50}, {p90}, {p99})");
        // bucket resolution is ±~30%: generous envelopes
        assert!(p50 > 30.0 && p50 < 80.0, "p50 {p50}");
        assert!(p99 > 70.0 && p99 <= 100.0, "p99 {p99}");
        assert!((mean - 50.5).abs() < 1e-9);
        assert_eq!(h.percentile(100.0), 100.0);
    }

    #[test]
    fn latency_histogram_extremes_and_merge() {
        let mut a = LatencyHistogram::new();
        a.record(0.0); // below the first bound
        a.record(1e9); // beyond the last bound
        assert_eq!(a.percentile(0.0), 0.0);
        assert_eq!(a.percentile(100.0), 1e9);
        let mut b = LatencyHistogram::new();
        b.record(5.0);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.max_ms(), 1e9);
        assert!(a.percentile(50.0) >= 0.0);
    }

    #[test]
    fn qps_meter_counts() {
        let mut q = QpsMeter::new();
        q.hit(10);
        q.hit(5);
        assert_eq!(q.count(), 15);
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(q.qps() > 0.0);
        assert!(q.elapsed_secs() > 0.0);
    }
}
