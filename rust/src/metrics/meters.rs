//! Streaming metric accumulators used by the trainer and evaluators.

use super::{auc, logloss_from_logits};

/// Running mean of per-step training loss.
#[derive(Clone, Debug, Default)]
pub struct LossMeter {
    sum: f64,
    n: usize,
}

impl LossMeter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn update(&mut self, loss: f64) {
        self.sum += loss;
        self.n += 1;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn reset(&mut self) {
        self.sum = 0.0;
        self.n = 0;
    }
}

/// Collects (logit, label) pairs across eval batches, then computes AUC
/// and logloss in one pass. Padding rows are dropped via `valid`.
#[derive(Clone, Debug, Default)]
pub struct EvalAccumulator {
    logits: Vec<f32>,
    labels: Vec<u8>,
}

impl EvalAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append the first `valid` entries of a batch's outputs.
    pub fn push(&mut self, logits: &[f32], labels: &[f32], valid: usize) {
        assert!(valid <= logits.len() && valid <= labels.len());
        self.logits.extend_from_slice(&logits[..valid]);
        self.labels.extend(labels[..valid].iter().map(|&y| y as u8));
    }

    pub fn n(&self) -> usize {
        self.labels.len()
    }

    pub fn auc(&self) -> f64 {
        auc(&self.logits, &self.labels)
    }

    pub fn logloss(&self) -> f64 {
        logloss_from_logits(&self.logits, &self.labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_meter_mean() {
        let mut m = LossMeter::new();
        assert_eq!(m.mean(), 0.0);
        m.update(1.0);
        m.update(3.0);
        assert_eq!(m.mean(), 2.0);
        assert_eq!(m.count(), 2);
        m.reset();
        assert_eq!(m.count(), 0);
    }

    #[test]
    fn eval_accumulator_drops_padding() {
        let mut acc = EvalAccumulator::new();
        acc.push(&[2.0, -1.0, 9.9], &[1.0, 0.0, 1.0], 2); // last row is padding
        acc.push(&[0.5], &[1.0], 1);
        assert_eq!(acc.n(), 3);
        assert!((acc.auc() - 1.0).abs() < 1e-12);
        assert!(acc.logloss() > 0.0);
    }
}
