//! Streaming metric accumulators used by the trainer, the evaluators and
//! the online serving tier.
//!
//! The latency histogram and QPS meter moved to [`crate::obs::hist`]
//! (one bucket-math implementation shared with the lock-free
//! [`crate::obs::registry::AtomicHistogram`]); `LatencyHistogram` and
//! `QpsMeter` stay re-exported here so the serving API is unchanged,
//! and their edge-case tests stay in this module as the behavioral pin.

use super::{auc, logloss_from_logits};

pub use crate::obs::hist::{Histogram as LatencyHistogram, QpsMeter};

/// Running mean of per-step training loss.
#[derive(Clone, Debug, Default)]
pub struct LossMeter {
    sum: f64,
    n: usize,
}

impl LossMeter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn update(&mut self, loss: f64) {
        self.sum += loss;
        self.n += 1;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn reset(&mut self) {
        self.sum = 0.0;
        self.n = 0;
    }
}

/// Collects (logit, label) pairs across eval batches, then computes AUC
/// and logloss in one pass. Padding rows are dropped via `valid`.
#[derive(Clone, Debug, Default)]
pub struct EvalAccumulator {
    logits: Vec<f32>,
    labels: Vec<u8>,
}

impl EvalAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append the first `valid` entries of a batch's outputs.
    pub fn push(&mut self, logits: &[f32], labels: &[f32], valid: usize) {
        assert!(valid <= logits.len() && valid <= labels.len());
        self.logits.extend_from_slice(&logits[..valid]);
        self.labels.extend(labels[..valid].iter().map(|&y| y as u8));
    }

    pub fn n(&self) -> usize {
        self.labels.len()
    }

    pub fn auc(&self) -> f64 {
        auc(&self.logits, &self.labels)
    }

    pub fn logloss(&self) -> f64 {
        logloss_from_logits(&self.logits, &self.labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_meter_mean() {
        let mut m = LossMeter::new();
        assert_eq!(m.mean(), 0.0);
        m.update(1.0);
        m.update(3.0);
        assert_eq!(m.mean(), 2.0);
        assert_eq!(m.count(), 2);
        m.reset();
        assert_eq!(m.count(), 0);
    }

    #[test]
    fn eval_accumulator_drops_padding() {
        let mut acc = EvalAccumulator::new();
        acc.push(&[2.0, -1.0, 9.9], &[1.0, 0.0, 1.0], 2); // last row is padding
        acc.push(&[0.5], &[1.0], 1);
        assert_eq!(acc.n(), 3);
        assert!((acc.auc() - 1.0).abs() < 1e-12);
        assert!(acc.logloss() > 0.0);
    }

    // The histogram/QPS edge-case tests below pin the serving-facing
    // behavior of the re-exported `obs::hist` types: empty → 0.0,
    // single-sample and all-equal exact, monotone percentiles, extremes
    // and merge. They ran against the in-module implementation before
    // the move and must keep passing unchanged.

    #[test]
    fn latency_histogram_empty_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_ms(), 0.0);
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.percentile(99.0), 0.0);
        assert_eq!(h.max_ms(), 0.0);
    }

    #[test]
    fn latency_histogram_single_sample_is_exact() {
        let mut h = LatencyHistogram::new();
        h.record(3.7);
        for p in [0.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(h.percentile(p), 3.7, "p{p}");
        }
        assert!((h.mean_ms() - 3.7).abs() < 1e-12);
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn latency_histogram_all_equal_is_exact() {
        let mut h = LatencyHistogram::new();
        for _ in 0..1000 {
            h.record(0.25);
        }
        assert_eq!(h.percentile(50.0), 0.25);
        assert_eq!(h.percentile(99.0), 0.25);
        assert!((h.mean_ms() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn latency_histogram_percentiles_are_monotone_and_bucket_accurate() {
        let mut h = LatencyHistogram::new();
        // 1..=100 ms uniformly
        for i in 1..=100 {
            h.record(i as f64);
        }
        let (p50, p90, p99, mean) = h.summary();
        assert!(p50 <= p90 && p90 <= p99, "({p50}, {p90}, {p99})");
        // bucket resolution is ±~30%: generous envelopes
        assert!(p50 > 30.0 && p50 < 80.0, "p50 {p50}");
        assert!(p99 > 70.0 && p99 <= 100.0, "p99 {p99}");
        assert!((mean - 50.5).abs() < 1e-9);
        assert_eq!(h.percentile(100.0), 100.0);
    }

    #[test]
    fn latency_histogram_extremes_and_merge() {
        let mut a = LatencyHistogram::new();
        a.record(0.0); // below the first bound
        a.record(1e9); // beyond the last bound
        assert_eq!(a.percentile(0.0), 0.0);
        assert_eq!(a.percentile(100.0), 1e9);
        let mut b = LatencyHistogram::new();
        b.record(5.0);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.max_ms(), 1e9);
        assert!(a.percentile(50.0) >= 0.0);
    }

    #[test]
    fn qps_meter_counts() {
        let mut q = QpsMeter::new();
        q.hit(10);
        q.hit(5);
        assert_eq!(q.count(), 15);
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(q.qps() > 0.0);
        assert!(q.elapsed_secs() > 0.0);
    }
}
