//! Evaluation metrics: exact AUC, logloss, and streaming accumulators.

pub mod auc;
pub mod calibration;
pub mod logloss;
pub mod meters;

pub use auc::auc;
pub use calibration::{brier_from_logits, ece_from_logits};
pub use logloss::{logloss, logloss_from_logits, sigmoid};
pub use meters::{EvalAccumulator, LatencyHistogram, LossMeter, QpsMeter};
