//! `cowclip` binary — CLI launcher for the coordinator, data tools and
//! the experiment harness. See `cowclip help`.

use cowclip::Result;

mod cli_shim {
    // The cli module lives in the library so examples/tests can reuse the
    // arg parser; re-exported here for the binary.
    pub use cowclip::cli::{dispatch, Args};
}

fn main() -> Result<()> {
    let args = cli_shim::Args::parse(std::env::args().skip(1))?;
    cli_shim::dispatch(args)
}
