//! Minimal host-side tensor used across the coordinator.
//!
//! The coordinator moves flat f32/i32 buffers between the data pipeline,
//! the all-reduce tree and the PJRT runtime; it never does heavy math on
//! them (that is L1/L2's job), so a deliberately small row-major tensor
//! with shape checking is all we need — no views, no broadcasting.

mod host;
mod sparse;

pub use host::{Dtype, Tensor};
pub use sparse::{merge_row_slices, GradTensor, SparseRowRangeMut, SparseRows};
