//! Row-major host tensor (f32 or i32) with shape bookkeeping.

use anyhow::{bail, Result};

/// Element type of a [`Tensor`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }
}

/// Dense row-major host tensor.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::I32 { shape, data }
    }

    /// All-zero f32 tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor::f32(shape.to_vec(), vec![0.0; shape.iter().product()])
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            Tensor::F32 { .. } => Dtype::F32,
            Tensor::I32 { .. } => Dtype::I32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            Tensor::I32 { .. } => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            Tensor::I32 { .. } => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            Tensor::F32 { .. } => bail!("expected i32 tensor, got f32"),
        }
    }

    /// Elementwise `self += alpha * other` (f32 only, shapes must match).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) -> Result<()> {
        if self.shape() != other.shape() {
            bail!("axpy shape mismatch {:?} vs {:?}", self.shape(), other.shape());
        }
        let dst = self.as_f32_mut()?;
        let src = other.as_f32()?;
        for (d, s) in dst.iter_mut().zip(src) {
            *d += alpha * s;
        }
        Ok(())
    }

    /// Elementwise `self *= alpha` (f32 only).
    pub fn scale(&mut self, alpha: f32) -> Result<()> {
        for d in self.as_f32_mut()? {
            *d *= alpha;
        }
        Ok(())
    }

    /// L2 norm (f32 only).
    pub fn norm(&self) -> Result<f64> {
        Ok(self
            .as_f32()?
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt())
    }

    /// Convert to an [`xla::Literal`] for PJRT execution.
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
            Tensor::I32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
        };
        Ok(lit)
    }

    /// Rebuild from an [`xla::Literal`] (f32 and i32 element types only).
    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::f32(dims, lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(Tensor::i32(dims, lit.to_vec::<i32>()?)),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_accounting() {
        let t = Tensor::f32(vec![2, 3], vec![1.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), Dtype::F32);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::f32(vec![2, 2], vec![0.0; 5]);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Tensor::f32(vec![3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::f32(vec![3], vec![10.0, 20.0, 30.0]);
        a.axpy(0.5, &b).unwrap();
        assert_eq!(a.as_f32().unwrap(), &[6.0, 12.0, 18.0]);
        a.scale(2.0).unwrap();
        assert_eq!(a.as_f32().unwrap(), &[12.0, 24.0, 36.0]);
    }

    #[test]
    fn axpy_rejects_shape_mismatch() {
        let mut a = Tensor::zeros(&[2]);
        let b = Tensor::zeros(&[3]);
        assert!(a.axpy(1.0, &b).is_err());
    }

    #[test]
    fn dtype_guards() {
        let t = Tensor::i32(vec![2], vec![1, 2]);
        assert!(t.as_f32().is_err());
        assert!(t.as_i32().is_ok());
    }

    #[test]
    fn norm() {
        let t = Tensor::f32(vec![2], vec![3.0, 4.0]);
        assert!((t.norm().unwrap() - 5.0).abs() < 1e-12);
    }
}
