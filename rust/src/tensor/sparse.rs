//! Row-sparse tensors over `[n_rows, d]` tables.
//!
//! A CTR batch touches only a tiny fraction of the embedding vocabulary,
//! so its embedding gradient is row-sparse: `(row_ids, vals)` with
//! `row_ids` sorted unique and `vals` holding `ids.len() * d` floats.
//! [`SparseRows`] is that representation; [`GradTensor`] is the dense-or-
//! sparse sum type the coordinator moves through accumulate → all-reduce
//! → clip → optimizer, keeping the per-step embedding cost
//! O(touched · d) instead of O(V · d).
//!
//! Per-id occurrence counts travel as a `SparseRows` with `d = 1` over
//! the same id set, so Alg. 1's `cnt(id)` never densifies either.

use anyhow::{bail, ensure, Result};

use super::host::Tensor;

/// Row-sparse view of an `[n_rows, d]` f32 table: sorted unique row ids
/// plus a packed `[nnz, d]` value block.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseRows {
    n_rows: usize,
    d: usize,
    ids: Vec<u32>,
    vals: Vec<f32>,
}

impl SparseRows {
    /// Build from parts. `ids` must be sorted, unique and `< n_rows`;
    /// `vals.len()` must equal `ids.len() * d`.
    pub fn new(n_rows: usize, d: usize, ids: Vec<u32>, vals: Vec<f32>) -> SparseRows {
        assert!(d > 0, "row width must be positive");
        assert_eq!(vals.len(), ids.len() * d, "ids/vals length mismatch");
        debug_assert!(ids.windows(2).all(|w| w[0] < w[1]), "ids must be sorted unique");
        debug_assert!(ids.last().map_or(true, |&id| (id as usize) < n_rows));
        SparseRows { n_rows, d, ids, vals }
    }

    /// All-zero (no touched rows).
    pub fn empty(n_rows: usize, d: usize) -> SparseRows {
        SparseRows::new(n_rows, d, Vec::new(), Vec::new())
    }

    /// Build from untrusted parts (e.g. decoded off the wire): the
    /// invariants [`SparseRows::new`] asserts are checked here and
    /// reported as errors instead of panics.
    pub fn validated(n_rows: usize, d: usize, ids: Vec<u32>, vals: Vec<f32>) -> Result<SparseRows> {
        ensure!(d > 0, "sparse: row width must be positive");
        ensure!(
            vals.len() == ids.len() * d,
            "sparse: {} values for {} rows of width {d}",
            vals.len(),
            ids.len()
        );
        ensure!(
            ids.windows(2).all(|w| w[0] < w[1]),
            "sparse: row ids must be sorted and unique"
        );
        if let Some(&last) = ids.last() {
            ensure!(
                (last as usize) < n_rows,
                "sparse: row id {last} out of range for {n_rows} rows"
            );
        }
        Ok(SparseRows { n_rows, d, ids, vals })
    }

    /// Scan a dense table and keep its nonzero rows.
    pub fn from_dense(dense: &[f32], n_rows: usize, d: usize) -> SparseRows {
        assert_eq!(dense.len(), n_rows * d, "dense length mismatch");
        let mut ids = Vec::new();
        let mut vals = Vec::new();
        for r in 0..n_rows {
            let row = &dense[r * d..(r + 1) * d];
            if row.iter().any(|&x| x != 0.0) {
                ids.push(r as u32);
                vals.extend_from_slice(row);
            }
        }
        SparseRows { n_rows, d, ids, vals }
    }

    /// Gather the given (sorted unique) rows out of a dense table.
    pub fn gather(dense: &[f32], n_rows: usize, d: usize, ids: Vec<u32>) -> SparseRows {
        assert_eq!(dense.len(), n_rows * d, "dense length mismatch");
        let mut vals = Vec::with_capacity(ids.len() * d);
        for &id in &ids {
            vals.extend_from_slice(&dense[id as usize * d..(id as usize + 1) * d]);
        }
        SparseRows::new(n_rows, d, ids, vals)
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Number of stored (touched) rows.
    pub fn nnz(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    pub fn ids(&self) -> &[u32] {
        &self.ids
    }

    pub fn vals(&self) -> &[f32] {
        &self.vals
    }

    pub fn vals_mut(&mut self) -> &mut [f32] {
        &mut self.vals
    }

    /// Split borrow: ids (shared) + vals (mutable), for in-place passes
    /// that index rows while rewriting values.
    pub fn ids_vals_mut(&mut self) -> (&[u32], &mut [f32]) {
        (&self.ids, &mut self.vals)
    }

    /// The `k`-th stored row's values.
    pub fn row(&self, k: usize) -> &[f32] {
        &self.vals[k * self.d..(k + 1) * self.d]
    }

    /// Storage slot of a row id, if present.
    pub fn find(&self, id: u32) -> Option<usize> {
        self.ids.binary_search(&id).ok()
    }

    /// For `d == 1` tables (counts): value at `id`, 0.0 when untouched.
    pub fn value_at(&self, id: u32) -> f32 {
        debug_assert_eq!(self.d, 1);
        self.find(id).map_or(0.0, |k| self.vals[k])
    }

    pub fn scale(&mut self, alpha: f32) {
        for v in &mut self.vals {
            *v *= alpha;
        }
    }

    /// `self += alpha * other` via a sorted-union merge: cost is
    /// O((nnz_a + nnz_b) · d), independent of `n_rows`.
    pub fn axpy(&mut self, alpha: f32, other: &SparseRows) -> Result<()> {
        ensure!(
            self.n_rows == other.n_rows && self.d == other.d,
            "sparse axpy shape mismatch: [{}, {}] vs [{}, {}]",
            self.n_rows,
            self.d,
            other.n_rows,
            other.d
        );
        if other.ids.is_empty() {
            return Ok(());
        }
        if self.ids.is_empty() {
            self.ids = other.ids.clone();
            self.vals = other.vals.iter().map(|&x| alpha * x).collect();
            return Ok(());
        }
        let d = self.d;
        let mut ids = Vec::with_capacity(self.ids.len() + other.ids.len());
        let mut vals = Vec::with_capacity(self.vals.len() + other.vals.len());
        let (mut i, mut j) = (0usize, 0usize);
        while i < self.ids.len() || j < other.ids.len() {
            let take_a = j >= other.ids.len()
                || (i < self.ids.len() && self.ids[i] < other.ids[j]);
            let take_b = i >= self.ids.len()
                || (j < other.ids.len() && other.ids[j] < self.ids[i]);
            if take_a {
                ids.push(self.ids[i]);
                vals.extend_from_slice(&self.vals[i * d..(i + 1) * d]);
                i += 1;
            } else if take_b {
                ids.push(other.ids[j]);
                vals.extend(other.vals[j * d..(j + 1) * d].iter().map(|&x| alpha * x));
                j += 1;
            } else {
                ids.push(self.ids[i]);
                let base = vals.len();
                vals.extend_from_slice(&self.vals[i * d..(i + 1) * d]);
                for (v, &o) in vals[base..].iter_mut().zip(&other.vals[j * d..(j + 1) * d]) {
                    *v += alpha * o;
                }
                i += 1;
                j += 1;
            }
        }
        self.ids = ids;
        self.vals = vals;
        Ok(())
    }

    /// Scatter-add `alpha * self` into a dense `[n_rows * d]` buffer.
    pub fn add_into_dense(&self, alpha: f32, dense: &mut [f32]) -> Result<()> {
        ensure!(
            dense.len() == self.n_rows * self.d,
            "dense target length {} != {} * {}",
            dense.len(),
            self.n_rows,
            self.d
        );
        let d = self.d;
        for (k, &id) in self.ids.iter().enumerate() {
            let dst = &mut dense[id as usize * d..(id as usize + 1) * d];
            for (t, &v) in dst.iter_mut().zip(&self.vals[k * d..(k + 1) * d]) {
                *t += alpha * v;
            }
        }
        Ok(())
    }

    /// Materialize the full dense `[n_rows * d]` buffer.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut dense = vec![0.0f32; self.n_rows * self.d];
        let d = self.d;
        for (k, &id) in self.ids.iter().enumerate() {
            dense[id as usize * d..(id as usize + 1) * d]
                .copy_from_slice(&self.vals[k * d..(k + 1) * d]);
        }
        dense
    }

    /// Materialize as a dense `[n_rows, d]` tensor.
    pub fn to_tensor(&self) -> Tensor {
        Tensor::f32(vec![self.n_rows, self.d], self.to_dense())
    }

    /// Bytes a network would move for this payload (ids + vals).
    pub fn payload_bytes(&self) -> u64 {
        (self.ids.len() * 4 + self.vals.len() * 4) as u64
    }

    /// Immutable view of the stored rows whose ids fall in `[lo, hi)`:
    /// `(ids, vals)` slices. The deferred-merge apply path slices both
    /// halves of the root reduction per shard row range with this.
    pub fn range_slice(&self, lo: usize, hi: usize) -> (&[u32], &[f32]) {
        let a = self.ids.partition_point(|&id| (id as usize) < lo);
        let b = self.ids.partition_point(|&id| (id as usize) < hi);
        (&self.ids[a..b], &self.vals[a * self.d..b * self.d])
    }

    /// Split the stored rows into disjoint mutable row-range views, one
    /// per range. `ranges` must be ascending, non-overlapping `[lo, hi)`
    /// pairs; stored rows outside every range are not reachable through
    /// the views (the shard-apply caller passes ranges covering the whole
    /// table). Each view keeps the *global* ids — the `base` field tells
    /// range-local code how to rebase them into its slice of the table.
    pub fn range_views_mut(&mut self, ranges: &[(usize, usize)]) -> Vec<SparseRowRangeMut<'_>> {
        let d = self.d;
        let mut out = Vec::with_capacity(ranges.len());
        let mut ids_rest: &[u32] = &self.ids;
        let mut vals_rest: &mut [f32] = &mut self.vals;
        let mut prev_hi = 0usize;
        for &(lo, hi) in ranges {
            assert!(lo >= prev_hi && lo <= hi && hi <= self.n_rows, "bad range [{lo}, {hi})");
            prev_hi = hi;
            let start = ids_rest.partition_point(|&id| (id as usize) < lo);
            let end = ids_rest.partition_point(|&id| (id as usize) < hi);
            let vr = std::mem::take(&mut vals_rest);
            let (_, vr) = vr.split_at_mut(start * d);
            let (take_v, vr) = vr.split_at_mut((end - start) * d);
            vals_rest = vr;
            let take_i = &ids_rest[start..end];
            ids_rest = &ids_rest[end..];
            out.push(SparseRowRangeMut { base: lo, rows: hi - lo, d, ids: take_i, vals: take_v });
        }
        out
    }
}

/// A mutable view of the stored rows of a [`SparseRows`] whose ids fall
/// in `[base, base + rows)` — the unit of work the shard-owned apply
/// stage hands each parameter shard.
#[derive(Debug)]
pub struct SparseRowRangeMut<'a> {
    /// First table row of the range (global).
    pub base: usize,
    /// Table rows spanned by the range.
    pub rows: usize,
    /// Row width.
    pub d: usize,
    /// Global ids of the stored rows inside the range (sorted unique).
    pub ids: &'a [u32],
    /// Packed values of those rows (`ids.len() * d`).
    pub vals: &'a mut [f32],
}

/// Union-merge two sorted packed row slices: `out = a + b` row-wise
/// (rows present in both add element-wise, rows in one side copy
/// through). This is exactly the arithmetic of
/// [`SparseRows::axpy`]`(1.0, ..)` restricted to a range, so merging a
/// reduction's two halves per row range is bitwise identical to merging
/// the whole tables and slicing afterwards — the invariant the
/// deferred-root-merge apply path rests on.
pub fn merge_row_slices(
    a_ids: &[u32],
    a_vals: &[f32],
    b_ids: &[u32],
    b_vals: &[f32],
    d: usize,
) -> (Vec<u32>, Vec<f32>) {
    debug_assert_eq!(a_vals.len(), a_ids.len() * d);
    debug_assert_eq!(b_vals.len(), b_ids.len() * d);
    let mut ids = Vec::with_capacity(a_ids.len() + b_ids.len());
    let mut vals = Vec::with_capacity(a_vals.len() + b_vals.len());
    let (mut i, mut j) = (0usize, 0usize);
    while i < a_ids.len() || j < b_ids.len() {
        let take_a = j >= b_ids.len() || (i < a_ids.len() && a_ids[i] < b_ids[j]);
        let take_b = i >= a_ids.len() || (j < b_ids.len() && b_ids[j] < a_ids[i]);
        if take_a {
            ids.push(a_ids[i]);
            vals.extend_from_slice(&a_vals[i * d..(i + 1) * d]);
            i += 1;
        } else if take_b {
            ids.push(b_ids[j]);
            vals.extend_from_slice(&b_vals[j * d..(j + 1) * d]);
            j += 1;
        } else {
            ids.push(a_ids[i]);
            let base = vals.len();
            vals.extend_from_slice(&a_vals[i * d..(i + 1) * d]);
            for (v, &o) in vals[base..].iter_mut().zip(&b_vals[j * d..(j + 1) * d]) {
                *v += o;
            }
            i += 1;
            j += 1;
        }
    }
    (ids, vals)
}

/// A gradient tensor that is either dense (HLO path, dense MLP params)
/// or row-sparse (embedding/wide tables on the reference path).
#[derive(Clone, Debug)]
pub enum GradTensor {
    Dense(Tensor),
    Sparse(SparseRows),
}

impl GradTensor {
    /// Does this gradient match a parameter of the given dense shape?
    /// A sparse gradient over `[n_rows, d]` matches exactly that shape.
    pub fn matches_shape(&self, shape: &[usize]) -> bool {
        match self {
            GradTensor::Dense(t) => t.shape() == shape,
            GradTensor::Sparse(s) => shape == [s.n_rows(), s.d()],
        }
    }

    /// Densify into a `[n_rows, d]` tensor (clones dense payloads).
    pub fn to_tensor(&self) -> Tensor {
        match self {
            GradTensor::Dense(t) => t.clone(),
            GradTensor::Sparse(s) => s.to_tensor(),
        }
    }

    pub fn scale(&mut self, alpha: f32) -> Result<()> {
        match self {
            GradTensor::Dense(t) => t.scale(alpha),
            GradTensor::Sparse(s) => {
                s.scale(alpha);
                Ok(())
            }
        }
    }

    /// `self += alpha * other`. Sparse+sparse stays sparse; a dense
    /// operand on either side densifies the result.
    pub fn axpy(&mut self, alpha: f32, other: &GradTensor) -> Result<()> {
        if matches!(self, GradTensor::Sparse(_)) && matches!(other, GradTensor::Dense(_)) {
            let dense = self.to_tensor();
            *self = GradTensor::Dense(dense);
        }
        match (&mut *self, other) {
            (GradTensor::Dense(a), GradTensor::Dense(b)) => a.axpy(alpha, b),
            (GradTensor::Sparse(a), GradTensor::Sparse(b)) => a.axpy(alpha, b),
            (GradTensor::Dense(a), GradTensor::Sparse(b)) => {
                if a.shape() != [b.n_rows(), b.d()] {
                    bail!(
                        "grad axpy shape mismatch: {:?} vs sparse [{}, {}]",
                        a.shape(),
                        b.n_rows(),
                        b.d()
                    );
                }
                b.add_into_dense(alpha, a.as_f32_mut()?)
            }
            (GradTensor::Sparse(_), GradTensor::Dense(_)) => unreachable!("densified above"),
        }
    }

    /// Bytes a network would move for this payload.
    pub fn payload_bytes(&self) -> u64 {
        match self {
            GradTensor::Dense(t) => (t.len() * 4) as u64,
            GradTensor::Sparse(s) => s.payload_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sp(n_rows: usize, d: usize, ids: &[u32], vals: &[f32]) -> SparseRows {
        SparseRows::new(n_rows, d, ids.to_vec(), vals.to_vec())
    }

    #[test]
    fn dense_roundtrip() {
        let s = sp(4, 2, &[1, 3], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.to_dense(), vec![0.0, 0.0, 1.0, 2.0, 0.0, 0.0, 3.0, 4.0]);
        let back = SparseRows::from_dense(&s.to_dense(), 4, 2);
        assert_eq!(back, s);
        assert_eq!(s.to_tensor().shape(), &[4, 2]);
    }

    #[test]
    fn gather_picks_rows() {
        let dense = [10.0f32, 11.0, 20.0, 21.0, 30.0, 31.0];
        let s = SparseRows::gather(&dense, 3, 2, vec![0, 2]);
        assert_eq!(s.vals(), &[10.0, 11.0, 30.0, 31.0]);
        assert_eq!(s.row(1), &[30.0, 31.0]);
        assert_eq!(s.find(2), Some(1));
        assert_eq!(s.find(1), None);
    }

    #[test]
    fn axpy_merges_sorted_union() {
        let mut a = sp(6, 1, &[0, 2, 5], &[1.0, 2.0, 3.0]);
        let b = sp(6, 1, &[1, 2, 4], &[10.0, 20.0, 30.0]);
        a.axpy(0.5, &b).unwrap();
        assert_eq!(a.ids(), &[0, 1, 2, 4, 5]);
        assert_eq!(a.vals(), &[1.0, 5.0, 12.0, 15.0, 3.0]);
        // equivalent to the dense computation
        let mut dense = sp(6, 1, &[0, 2, 5], &[1.0, 2.0, 3.0]).to_dense();
        for (x, y) in dense.iter_mut().zip(b.to_dense()) {
            *x += 0.5 * y;
        }
        assert_eq!(a.to_dense(), dense);
    }

    #[test]
    fn axpy_into_empty_scales() {
        let mut a = SparseRows::empty(4, 2);
        let b = sp(4, 2, &[1], &[2.0, -4.0]);
        a.axpy(0.25, &b).unwrap();
        assert_eq!(a.ids(), &[1]);
        assert_eq!(a.vals(), &[0.5, -1.0]);
    }

    #[test]
    fn axpy_rejects_shape_mismatch() {
        let mut a = SparseRows::empty(4, 2);
        assert!(a.axpy(1.0, &SparseRows::empty(4, 3)).is_err());
        assert!(a.axpy(1.0, &SparseRows::empty(5, 2)).is_err());
    }

    #[test]
    fn value_at_for_counts() {
        let c = sp(5, 1, &[1, 4], &[2.0, 7.0]);
        assert_eq!(c.value_at(1), 2.0);
        assert_eq!(c.value_at(0), 0.0);
        assert_eq!(c.value_at(4), 7.0);
    }

    #[test]
    fn add_into_dense_scatters() {
        let s = sp(3, 2, &[0, 2], &[1.0, 1.0, 2.0, 2.0]);
        let mut dense = vec![1.0f32; 6];
        s.add_into_dense(2.0, &mut dense).unwrap();
        assert_eq!(dense, vec![3.0, 3.0, 1.0, 1.0, 5.0, 5.0]);
    }

    #[test]
    fn grad_tensor_axpy_all_combinations() {
        let dense = |v: &[f32]| GradTensor::Dense(Tensor::f32(vec![3, 1], v.to_vec()));
        let sparse = |ids: &[u32], v: &[f32]| GradTensor::Sparse(sp(3, 1, ids, v));

        // dense += dense
        let mut a = dense(&[1.0, 2.0, 3.0]);
        a.axpy(1.0, &dense(&[1.0, 1.0, 1.0])).unwrap();
        assert_eq!(a.to_tensor().as_f32().unwrap(), &[2.0, 3.0, 4.0]);
        // dense += sparse
        let mut a = dense(&[1.0, 2.0, 3.0]);
        a.axpy(2.0, &sparse(&[1], &[5.0])).unwrap();
        assert_eq!(a.to_tensor().as_f32().unwrap(), &[1.0, 12.0, 3.0]);
        // sparse += sparse
        let mut a = sparse(&[0], &[1.0]);
        a.axpy(1.0, &sparse(&[2], &[3.0])).unwrap();
        assert!(matches!(a, GradTensor::Sparse(_)));
        assert_eq!(a.to_tensor().as_f32().unwrap(), &[1.0, 0.0, 3.0]);
        // sparse += dense densifies
        let mut a = sparse(&[0], &[1.0]);
        a.axpy(1.0, &dense(&[1.0, 1.0, 1.0])).unwrap();
        assert!(matches!(a, GradTensor::Dense(_)));
        assert_eq!(a.to_tensor().as_f32().unwrap(), &[2.0, 1.0, 1.0]);
    }

    #[test]
    fn payload_bytes_reflect_sparsity() {
        let s = GradTensor::Sparse(sp(1000, 4, &[7], &[1.0, 2.0, 3.0, 4.0]));
        assert_eq!(s.payload_bytes(), 4 + 16);
        let d = GradTensor::Dense(Tensor::zeros(&[1000, 4]));
        assert_eq!(d.payload_bytes(), 16_000);
    }

    #[test]
    fn range_views_partition_stored_rows() {
        let mut s = sp(10, 2, &[1, 3, 4, 8], &[1.0, 1.5, 3.0, 3.5, 4.0, 4.5, 8.0, 8.5]);
        let views = s.range_views_mut(&[(0, 4), (4, 7), (7, 10)]);
        assert_eq!(views.len(), 3);
        assert_eq!(views[0].ids, &[1, 3]);
        assert_eq!(views[0].base, 0);
        assert_eq!(&*views[0].vals, &[1.0, 1.5, 3.0, 3.5]);
        assert_eq!(views[1].ids, &[4]);
        assert_eq!(views[1].base, 4);
        assert_eq!(views[2].ids, &[8]);
        assert_eq!(&*views[2].vals, &[8.0, 8.5]);
        // views mutate the underlying storage
        views.into_iter().for_each(|v| v.vals.iter_mut().for_each(|x| *x *= 2.0));
        assert_eq!(s.row(0), &[2.0, 3.0]);
        assert_eq!(s.row(3), &[16.0, 17.0]);
    }

    #[test]
    fn range_slice_and_merge_match_whole_table_axpy() {
        let a = sp(10, 2, &[1, 4, 8], &[1.0, 1.5, 4.0, 4.5, 8.0, 8.5]);
        let b = sp(10, 2, &[0, 4, 9], &[0.1, 0.2, 40.0, 41.0, 9.0, 9.5]);
        // whole-table oracle: a + b via axpy(1.0)
        let mut whole = a.clone();
        whole.axpy(1.0, &b).unwrap();
        // per-range merges concatenate to the same ids/vals, bitwise
        let mut ids = Vec::new();
        let mut vals = Vec::new();
        for &(lo, hi) in &[(0usize, 4usize), (4, 7), (7, 10)] {
            let (ai, av) = a.range_slice(lo, hi);
            let (bi, bv) = b.range_slice(lo, hi);
            let (mi, mv) = merge_row_slices(ai, av, bi, bv, 2);
            ids.extend(mi);
            vals.extend(mv);
        }
        assert_eq!(ids, whole.ids());
        assert_eq!(vals, whole.vals());
        // empty-side merges copy through
        let (mi, mv) = merge_row_slices(&[], &[], &[2], &[5.0, 6.0], 2);
        assert_eq!(mi, vec![2]);
        assert_eq!(mv, vec![5.0, 6.0]);
    }

    #[test]
    fn range_views_handle_empty_ranges() {
        let mut s = sp(6, 1, &[5], &[7.0]);
        let views = s.range_views_mut(&[(0, 2), (2, 2), (2, 6)]);
        assert!(views[0].ids.is_empty() && views[0].vals.is_empty());
        assert!(views[1].ids.is_empty());
        assert_eq!(views[2].ids, &[5]);
        assert_eq!(views[2].rows, 4);
    }

    #[test]
    fn shape_matching() {
        let s = GradTensor::Sparse(SparseRows::empty(10, 3));
        assert!(s.matches_shape(&[10, 3]));
        assert!(!s.matches_shape(&[10, 4]));
        let d = GradTensor::Dense(Tensor::zeros(&[7]));
        assert!(d.matches_shape(&[7]));
    }
}
