//! Figure reproductions: Fig. 1 (step/total time vs batch), Fig. 3
//! (context), Fig. 4 (id frequency), Fig. 5 (column grad norms),
//! Fig. 7/8 (training curves).

use std::time::Instant;

use anyhow::Result;

use super::common::{fmt_auc, fmt_logloss, run_one, DataVariant, ExpContext, RunSpec};
use super::report::{Report, Table};
use crate::clip::ClipMode;
use crate::coordinator::{Trainer, TrainConfig};
use crate::data::batcher::Batcher;
use crate::data::stats::field_stats;
use crate::reference::ModelKind;
use crate::scaling::presets::{paper_label, BATCH_LADDER};
use crate::scaling::rules::ScalingRule;

/// Fig. 1: relative time of one optimizer step and of a full epoch as
/// batch size scales. On the paper's V100 the step time is ~flat to 8x
/// (GPU underutilized at small batch); on this CPU testbed the step time
/// grows with batch, but the *total* time still collapses because the
/// coordinator amortizes per-step overhead — both series are printed so
/// the reader sees which part transfers.
pub fn fig1(ctx: &ExpContext) -> Result<Report> {
    let data = ctx.data(DataVariant::Criteo)?;
    let train = &data.0;
    let preset = DataVariant::Criteo.preset();
    let mut table = Table::new(&[
        "batch (paper label)",
        "step time (ms)",
        "rel. step time",
        "steps/epoch",
        "epoch time (s)",
        "rel. epoch time",
    ]);

    let mut base_step = 0.0f64;
    let mut base_epoch = 0.0f64;
    for &(label, batch) in BATCH_LADDER.iter() {
        if batch > train.n() {
            continue;
        }
        let cfg = TrainConfig {
            batch,
            base_batch: preset.base_batch,
            base_hypers: preset.cowclip,
            rule: ScalingRule::CowClip,
            epochs: 1.0,
            workers: 1,
            threads: 1,      // sequential: this figure times the raw step
            param_shards: 1, // serial apply for the same reason
            warmup_steps: 0,
            init_sigma: preset.init_sigma_cowclip,
            seed: ctx.seed,
            eval_every_epochs: 0,
            verbose: false,
        };
        let engine = ctx.engine(ModelKind::DeepFm, DataVariant::Criteo, ClipMode::CowClip)?;
        let mut trainer = Trainer::new(engine, cfg)?;
        let mut batcher = Batcher::new(train, batch, 0);
        // warm the executable caches, then time a few steps
        let b0 = batcher.next_batch();
        trainer.train_step(&b0)?;
        let reps = if batch <= 512 { 5 } else { 2 };
        let t0 = Instant::now();
        for _ in 0..reps {
            let b = batcher.next_batch();
            trainer.train_step(&b)?;
        }
        let step_ms = t0.elapsed().as_secs_f64() * 1000.0 / reps as f64;
        let steps_per_epoch = train.n() / batch;
        let epoch_s = step_ms * steps_per_epoch as f64 / 1000.0;
        if base_step == 0.0 {
            base_step = step_ms;
            base_epoch = epoch_s;
        }
        table.row(vec![
            format!("{batch} ({label})"),
            format!("{step_ms:.1}"),
            format!("{:.2}x", step_ms / base_step),
            format!("{steps_per_epoch}"),
            format!("{epoch_s:.1}"),
            format!("{:.3}x", epoch_s / base_epoch),
        ]);
    }
    let body = format!(
        "{}\n*Paper: step time ~flat to 8x batch on V100 ⇒ near-linear total-time \
         reduction. CPU-PJRT step time grows with batch, so the epoch-time \
         reduction here comes from amortized coordinator overhead; the headline \
         shape (bigger batch ⇒ shorter total time at equal epochs) holds.*",
        table.to_markdown()
    );
    Ok(Report::new("fig1", "Relative training time vs batch size (DeepFM)", body))
}

/// Fig. 3: AUC progress of CTR models on Criteo over six years — a
/// context figure; we reprint the paper's digitized series to anchor the
/// "0.1% matters" sensitivity argument.
pub fn fig3(_ctx: &ExpContext) -> Result<Report> {
    let mut table = Table::new(&["year", "representative model", "AUC (%)"]);
    for (year, model, auc) in [
        (2016, "W&D", 79.0),
        (2017, "DCN / DeepFM", 79.7),
        (2018, "xDeepFM", 80.0),
        (2019, "AutoInt / FiBiNET", 80.3),
        (2020, "DCN-M", 80.6),
        (2021, "DCN v2 / open benchmark best", 80.9),
    ] {
        table.row(vec![year.to_string(), model.into(), format!("{auc:.1}")]);
    }
    let body = format!(
        "{}\n*Digitized from the paper's Figure 3 (context): six years of model \
         work moved Criteo AUC by <2%, which is why the paper treats a 0.1% AUC \
         change as significant and why large-batch training must be \
         accuracy-preserving.*",
        table.to_markdown()
    );
    Ok(Report::new("fig3", "Six years of Criteo AUC progress (paper data)", body))
}

/// Fig. 4: per-field id frequency distribution (log-scale histogram).
pub fn fig4(ctx: &ExpContext) -> Result<Report> {
    let data = ctx.data(DataVariant::Criteo)?;
    let stats = field_stats(&data.0);
    // pick three fields spanning the vocab range, like the paper's panels
    let picks = [0usize, 8, 18];
    let mut body = String::new();
    for &f in &picks {
        let s = &stats[f];
        body.push_str(&format!(
            "**Field {f}** (vocab {}, unseen {}): head-10 mass {:.1}%\n\n",
            s.vocab,
            s.n_unseen,
            100.0 * s.head_mass(10)
        ));
        let mut table = Table::new(&["count bucket (≤)", "#ids", "bar"]);
        for (ub, n) in s.log_histogram() {
            if n == 0 {
                continue;
            }
            let bar = "#".repeat(((n as f64).log2().max(0.0) as usize) + 1);
            table.row(vec![ub.to_string(), n.to_string(), bar]);
        }
        body.push_str(&table.to_markdown());
        body.push('\n');
    }
    body.push_str(
        "*Matches the paper's Figure 4 shape: within every field, id \
         frequencies span decades (log-scale y), so a fixed batch contains \
         hot ids ~always and tail ids ~never — the premise of Eq. (1).*",
    );
    Ok(Report::new("fig4", "Id frequency distribution across fields", body))
}

/// Fig. 5: L2-norm distribution of embedding-column gradients after some
/// training — shows why a single global clip threshold cannot fit all
/// columns.
pub fn fig5(ctx: &ExpContext) -> Result<Report> {
    let data = ctx.data(DataVariant::Criteo)?;
    let (train, _) = (&data.0, &data.1);
    let preset = DataVariant::Criteo.preset();
    let engine = ctx.engine(ModelKind::DeepFm, DataVariant::Criteo, ClipMode::CowClip)?;
    let cfg = TrainConfig {
        batch: 64,
        base_batch: preset.base_batch,
        base_hypers: preset.cowclip,
        rule: ScalingRule::CowClip,
        epochs: ctx.epochs.min(1.0),
        workers: 1,
        threads: 0,
        param_shards: 0,
        warmup_steps: 0,
        init_sigma: preset.init_sigma_cowclip,
        seed: ctx.seed,
        eval_every_epochs: 0,
        verbose: false,
    };
    let mut trainer = Trainer::new(engine, cfg)?;
    // train briefly (the paper snapshots step 1000; scaled: a few hundred)
    let mut batcher = Batcher::new(train, 64, 1);
    let steps = (train.n() / 64).min(400);
    for _ in 0..steps {
        let b = batcher.next_batch();
        trainer.train_step(&b)?;
    }
    // one gradient snapshot at batch 512
    let mut snap_batcher = Batcher::new(train, 512, 2);
    let batch = snap_batcher.next_batch();
    let params = trainer.params();
    let out = trainer.engine.grad(&params, &batch)?;
    let d = params.spec[0].shape[1];
    drop(params);
    // densify for this diagnostic (the embed grad is sparse on the
    // reference path, dense on the HLO path)
    let g_t = out.grads[0].to_tensor();
    let g = g_t.as_f32()?;
    let counts = out.counts.to_dense();
    let mut norms: Vec<f64> = Vec::new();
    for (i, row) in g.chunks(d).enumerate() {
        if counts[i] > 0.0 {
            norms.push(row.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt());
        }
    }
    norms.sort_by(f64::total_cmp);
    let mut table = Table::new(&["norm bucket", "#columns", "bar"]);
    let buckets = [1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0];
    let mut lo = 0.0f64;
    for &hi in &buckets {
        let n = norms.iter().filter(|&&x| x > lo && x <= hi).count();
        if n > 0 {
            let bar = "#".repeat(((n as f64).log2().max(0.0) as usize) + 1);
            table.row(vec![format!("({lo:.0e}, {hi:.0e}]"), n.to_string(), bar]);
        }
        lo = hi;
    }
    let spread = norms.last().unwrap_or(&0.0) / norms.first().unwrap_or(&1e-12).max(1e-12);
    let body = format!(
        "{}\nColumns with ids present in the batch: {}; norm spread \
         max/min ≈ {:.0}x.\n\n*Paper's Figure 5 point: per-column gradient \
         norms differ by orders of magnitude even after training, so global \
         or field-wise thresholds over/under-clip — motivating column-wise \
         adaptive clipping.*",
        table.to_markdown(),
        norms.len(),
        spread
    );
    Ok(Report::new("fig5", "Column gradient-norm distribution (step-1000 analog)", body))
}

/// Fig. 7/8: train/test AUC and loss vs epoch at several batch sizes.
pub fn fig7_8(ctx: &ExpContext) -> Result<Report> {
    let mut body = String::new();
    for batch in [64usize, 512, 4096] {
        let mut spec = RunSpec::cowclip(ModelKind::DeepFm, DataVariant::Criteo, batch);
        spec.warmup = true;
        let data = ctx.data(DataVariant::Criteo)?;
        if batch > data.0.n() {
            continue;
        }
        // per-epoch evals on
        let preset = DataVariant::Criteo.preset();
        let engine = ctx.engine(spec.model, spec.variant, spec.clip)?;
        let steps_per_epoch = (data.0.n() / batch).max(1);
        let cfg = TrainConfig {
            batch,
            base_batch: preset.base_batch,
            base_hypers: preset.cowclip,
            rule: ScalingRule::CowClip,
            epochs: ctx.epochs,
            workers: 1,
            threads: 0,
            param_shards: 0,
            warmup_steps: steps_per_epoch,
            init_sigma: preset.init_sigma_cowclip,
            seed: ctx.seed,
            eval_every_epochs: 1,
            verbose: false,
        };
        let mut trainer = Trainer::new(engine, cfg)?;
        let report = trainer.train(&data.0, &data.1)?;
        let label = paper_label(batch).unwrap_or("?");
        body.push_str(&format!("**batch {batch} (paper {label})**\n\n"));
        let mut table = Table::new(&["epoch", "train loss", "test AUC (%)", "test logloss"]);
        for e in &report.epoch_evals {
            table.row(vec![
                e.epoch.to_string(),
                format!("{:.4}", e.train_loss),
                fmt_auc(e.test_auc),
                fmt_logloss(e.test_logloss),
            ]);
        }
        body.push_str(&table.to_markdown());
        body.push('\n');
        let _ = run_one; // (grid helper not needed here)
    }
    body.push_str(
        "*Paper Figures 7/8: larger batches start slower in epoch-1 AUC but \
         converge to the same (or better) final quality under CowClip.*",
    );
    Ok(Report::new("fig7_8", "Training curves across batch sizes (CowClip)", body))
}
