//! Scaling-rule comparison tables: Table 2 (diagnosis), Table 4 (Criteo),
//! Table 10 (Criteo-seq), Table 11 (Avazu).

use anyhow::Result;

use super::common::{fmt_auc, fmt_logloss, run_one, DataVariant, ExpContext, RunSpec};
use super::report::{Report, Table};
use crate::reference::ModelKind;
use crate::scaling::presets::paper_label;
use crate::scaling::rules::ScalingRule;

const DIAG_BATCHES: [usize; 4] = [64, 128, 256, 512]; // paper 1K..8K

/// Table 2: No/Sqrt/Linear scaling on Criteo vs the top-3-id collapsed
/// Criteo. The deltas (not absolutes) are the object: rules fail on the
/// frequency-imbalanced data and work on the balanced one.
pub fn table2(ctx: &ExpContext) -> Result<Report> {
    let rules = [ScalingRule::NoScale, ScalingRule::Sqrt, ScalingRule::Linear];
    let mut body = String::new();
    for variant in [DataVariant::Criteo, DataVariant::CriteoTop3] {
        body.push_str(&format!("**{}**\n\n", variant.label()));
        let mut table = Table::new(&["batch", "No Scale", "Sqrt Scale", "Linear Scale"]);
        let mut base_auc = [0.0f64; 3];
        for (bi, &batch) in DIAG_BATCHES.iter().enumerate() {
            let mut cells = vec![format!("{batch} ({})", paper_label(batch).unwrap_or("-"))];
            for (ri, &rule) in rules.iter().enumerate() {
                let r = run_one(ctx, &RunSpec::baseline(ModelKind::DeepFm, variant, batch, rule))?;
                if bi == 0 {
                    base_auc[ri] = r.auc;
                    cells.push(fmt_auc(r.auc));
                } else if r.auc.is_nan() {
                    cells.push("diverge".into());
                } else {
                    cells.push(format!("{:+.2}", (r.auc - base_auc[ri]) * 100.0));
                }
            }
            table.row(cells);
        }
        body.push_str(&table.to_markdown());
        body.push('\n');
    }
    body.push_str(
        "*Paper Table 2: on real (imbalanced) Criteo, classic rules lose AUC \
         as batch grows; after collapsing every field to its top-3 ids (all \
         ids frequent) the same rules hold — frequency imbalance is the \
         failure cause. Expect the left block to degrade with batch and the \
         right block to stay ~flat.*",
    );
    Ok(Report::new("table2", "Classic scaling rules vs id frequency (DeepFM)", body))
}

fn scaling_grid(ctx: &ExpContext, variant: DataVariant, id: &str, title: &str) -> Result<Report> {
    // CowClip rows use the cowclip apply artifact; baselines use clip=none.
    let strategies: Vec<(&str, Box<dyn Fn(usize) -> RunSpec>)> = vec![
        (
            "No Scaling",
            Box::new(move |b| RunSpec::baseline(ModelKind::DeepFm, variant, b, ScalingRule::NoScale)),
        ),
        (
            "Sqrt Scaling",
            Box::new(move |b| RunSpec::baseline(ModelKind::DeepFm, variant, b, ScalingRule::Sqrt)),
        ),
        (
            "Sqrt Scaling*",
            Box::new(move |b| {
                RunSpec::baseline(ModelKind::DeepFm, variant, b, ScalingRule::SqrtStar)
            }),
        ),
        (
            "LR Scaling",
            Box::new(move |b| RunSpec::baseline(ModelKind::DeepFm, variant, b, ScalingRule::Linear)),
        ),
        (
            "n2-lambda Scaling (Ours)",
            Box::new(move |b| {
                RunSpec::baseline(ModelKind::DeepFm, variant, b, ScalingRule::N2Lambda)
            }),
        ),
        (
            "CowClip (Ours)",
            Box::new(move |b| RunSpec::cowclip(ModelKind::DeepFm, variant, b)),
        ),
    ];

    let mut header: Vec<String> = vec!["strategy".into()];
    for &b in &DIAG_BATCHES {
        header.push(format!("{b} AUC", b = paper_label(b).unwrap_or("?")));
        header.push("LogLoss".into());
    }
    let hdr_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr_refs);
    for (label, mk) in &strategies {
        let mut cells = vec![label.to_string()];
        for &batch in &DIAG_BATCHES {
            let r = run_one(ctx, &mk(batch))?;
            cells.push(fmt_auc(r.auc));
            cells.push(fmt_logloss(r.logloss));
        }
        table.row(cells);
    }
    let body = format!(
        "{}\n*Paper {}: traditional rules degrade by 4K-8K; n²-λ holds to 4K; \
         CowClip holds (or improves) across the whole span. Batch labels are \
         the paper's (our sizes are 1/16, DESIGN.md §4).*",
        table.to_markdown(),
        id
    );
    Ok(Report::new(id, title, body))
}

/// Table 4: all six strategies on Criteo, DeepFM.
pub fn table4(ctx: &ExpContext) -> Result<Report> {
    scaling_grid(
        ctx,
        DataVariant::Criteo,
        "table4",
        "Scaling strategies on Criteo(synth), DeepFM, 1K-8K labels",
    )
}

/// Table 10: scaling methods on Criteo-seq.
pub fn table10(ctx: &ExpContext) -> Result<Report> {
    scaling_grid(
        ctx,
        DataVariant::CriteoSeq,
        "table10",
        "Scaling strategies on Criteo-seq(synth), DeepFM",
    )
}

/// Table 11: scaling methods on Avazu.
pub fn table11(ctx: &ExpContext) -> Result<Report> {
    scaling_grid(
        ctx,
        DataVariant::Avazu,
        "table11",
        "Scaling strategies on Avazu(synth), DeepFM",
    )
}
