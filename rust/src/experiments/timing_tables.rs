//! Training-time tables: Table 6 (Criteo) and Table 13 (Avazu) — measured
//! wall-clock for our runs plus cost-model rows for the published systems.

use anyhow::Result;

use super::common::{fmt_auc, fmt_logloss, run_one, DataVariant, ExpContext, RunSpec};
use super::report::{Report, Table};
use crate::reference::ModelKind;
use crate::scaling::presets::BATCH_LADDER;
use crate::sim::{BaselineSystem, SimCostModel};

fn timing_table(
    ctx: &ExpContext,
    variant: DataVariant,
    id: &str,
    title: &str,
    models: &[ModelKind],
) -> Result<Report> {
    let n_train = ctx.data(variant)?.0.n();
    let batches: Vec<(&str, usize)> = BATCH_LADDER
        .iter()
        .filter(|&&(_, b)| b <= n_train)
        .copied()
        .collect();

    let mut header: Vec<String> =
        vec!["system".into(), "AUC (%)".into(), "LogLoss".into()];
    header.extend(batches.iter().map(|&(l, _)| format!("{l} (s)")));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr);

    // simulated baseline systems (paper quotes: minutes on their testbed;
    // we print their fitted cost-model minutes, capped at 4K batch)
    for sys in BaselineSystem::ALL {
        let (auc, ll) = sys.criteo_quality();
        let model = SimCostModel::for_system(sys);
        let mut cells = vec![
            format!("{} (sim, min)", sys.label()),
            format!("{auc:.1}"),
            format!("{ll:.3}"),
        ];
        for &(label, _) in &batches {
            // map our ladder label back to the paper batch for the model
            let paper_batch = match label {
                "1K" => 1024,
                "2K" => 2048,
                "4K" => 4096,
                _ => 0,
            };
            if paper_batch == 0 || paper_batch > sys.max_batch_paper() {
                cells.push("-".into());
            } else {
                let gpus = SimCostModel::paper_gpus_for_batch(paper_batch);
                cells.push(format!("{:.0}", model.minutes(paper_batch, gpus)));
            }
        }
        table.row(cells);
    }

    // our measured runs
    let mut deepfm_times: Vec<f64> = Vec::new();
    for &model in models {
        let mut auc_s = String::new();
        let mut ll_s = String::new();
        let mut cells_time = Vec::new();
        for (i, &(_, batch)) in batches.iter().enumerate() {
            let r = run_one(ctx, &RunSpec::cowclip(model, variant, batch))?;
            if i == 0 {
                auc_s = fmt_auc(r.auc);
                ll_s = fmt_logloss(r.logloss);
            }
            cells_time.push(format!("{:.1}", r.report.wall_seconds));
            if model == ModelKind::DeepFm {
                deepfm_times.push(r.report.wall_seconds);
            }
        }
        let mut cells = vec![format!("{} (CowClip)", model.label()), auc_s, ll_s];
        cells.extend(cells_time);
        table.row(cells);
    }

    // speedup row (DeepFM)
    if !deepfm_times.is_empty() {
        let base = deepfm_times[0];
        let mut cells = vec!["Speedup (DeepFM)".into(), "".into(), "".into()];
        for t in &deepfm_times {
            cells.push(format!("{:.2}x", base / t));
        }
        table.row(cells);
    }

    let body = format!(
        "{}\n*Paper Table {}: baselines (XDL/FAE/DLRM/Hotline) go faster only \
         by adding GPUs, cap at 4K batch and sit ≥0.6% AUC below; CowClip \
         scales the batch on one device with near-linear speedup to 16K and \
         ~{}x at 128K. Baseline rows are cost-model simulations (DESIGN.md \
         §4) in paper-minutes; our rows are measured seconds on this CPU \
         testbed — compare *speedup shapes*, not absolute units.*",
        table.to_markdown(),
        if id == "table6" { "6" } else { "13" },
        if id == "table6" { "77" } else { "44" },
    );
    Ok(Report::new(id, title, body))
}

/// Table 6: training time on Criteo.
pub fn table6(ctx: &ExpContext) -> Result<Report> {
    timing_table(
        ctx,
        DataVariant::Criteo,
        "table6",
        "Training time vs batch size, Criteo(synth)",
        &[ModelKind::DeepFm, ModelKind::WideDeep, ModelKind::Dcn, ModelKind::DcnV2],
    )
}

/// Table 13: training time on Avazu (DeepFM + DCNv2 to bound runtime).
pub fn table13(ctx: &ExpContext) -> Result<Report> {
    timing_table(
        ctx,
        DataVariant::Avazu,
        "table13",
        "Training time vs batch size, Avazu(synth)",
        &[ModelKind::DeepFm, ModelKind::DcnV2],
    )
}
