//! Shared experiment plumbing: datasets, run descriptors, one-shot runs.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::clip::ClipMode;
use crate::coordinator::{Engine, TrainConfig, TrainReport, Trainer};
use crate::data::dataset::Dataset;
use crate::data::split::{random_split, sequential_split};
use crate::data::synth::{generate, SynthConfig};
use crate::data::transform::{reindex_to_schema, topk_collapse};
use crate::reference::ModelKind;
use crate::runtime::Runtime;
use crate::scaling::presets::{avazu_preset, criteo_preset, DatasetPreset};
use crate::scaling::rules::ScalingRule;

/// Which evaluation dataset a run uses (paper terminology).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DataVariant {
    /// criteo_synth, random 90/10 split.
    Criteo,
    /// criteo_synth, sequential 6/7 split (Criteo-seq).
    CriteoSeq,
    /// criteo_synth collapsed to top-3 ids/field (Table 2 right).
    CriteoTop3,
    /// avazu_synth, random 80/20 split.
    Avazu,
}

impl DataVariant {
    pub fn schema_name(&self) -> &'static str {
        match self {
            DataVariant::Avazu => "avazu_synth",
            _ => "criteo_synth",
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            DataVariant::Criteo => "Criteo(synth)",
            DataVariant::CriteoSeq => "Criteo-seq(synth)",
            DataVariant::CriteoTop3 => "Criteo(synth, top-3 ids)",
            DataVariant::Avazu => "Avazu(synth)",
        }
    }

    pub fn preset(&self) -> DatasetPreset {
        match self {
            DataVariant::Avazu => avazu_preset(),
            _ => criteo_preset(),
        }
    }
}

/// Everything shared across experiments in one invocation.
pub struct ExpContext {
    pub runtime: Option<Arc<Runtime>>,
    /// Training rows to synthesize per dataset.
    pub n: usize,
    pub epochs: f64,
    pub seed: u64,
    /// Data-parallel workers in every run.
    pub workers: usize,
    cache: std::sync::Mutex<HashMap<DataVariant, Arc<(Dataset, Dataset)>>>,
}

impl ExpContext {
    pub fn new(runtime: Option<Arc<Runtime>>, n: usize, epochs: f64, seed: u64) -> ExpContext {
        ExpContext {
            runtime,
            n,
            epochs,
            seed,
            workers: 1,
            cache: std::sync::Mutex::new(HashMap::new()),
        }
    }

    /// (train, test) for a variant, generated once and cached.
    pub fn data(&self, variant: DataVariant) -> Result<Arc<(Dataset, Dataset)>> {
        if let Some(d) = self.cache.lock().unwrap().get(&variant) {
            return Ok(d.clone());
        }
        let schema = crate::data::schema::by_name(variant.schema_name())
            .context("unknown schema")?;
        let cfg = SynthConfig { n: self.n, seed: self.seed, ..Default::default() };
        let full = generate(&schema, &cfg);
        let pair = match variant {
            DataVariant::Criteo => random_split(&full, 0.9, self.seed),
            DataVariant::CriteoSeq => sequential_split(&full, 6.0 / 7.0),
            DataVariant::Avazu => random_split(&full, 0.8, self.seed),
            DataVariant::CriteoTop3 => {
                // collapse then reindex onto the artifact schema so the
                // HLO programs (compiled for the full vocab) can run it
                let collapsed = topk_collapse(&full, 3);
                let re = reindex_to_schema(&collapsed, &schema);
                random_split(&re, 0.9, self.seed)
            }
        };
        let arc = Arc::new(pair);
        self.cache.lock().unwrap().insert(variant, arc.clone());
        Ok(arc)
    }

    /// Build an engine for (model, variant, clip).
    pub fn engine(&self, model: ModelKind, variant: DataVariant, clip: ClipMode) -> Result<Engine> {
        match &self.runtime {
            Some(rt) => Engine::hlo(rt.clone(), model, variant.schema_name(), clip),
            None => {
                let schema = crate::data::schema::by_name(variant.schema_name()).unwrap();
                Ok(Engine::reference(model, schema, 10, vec![128, 128, 128], 3, clip))
            }
        }
    }
}

/// One experimental run descriptor.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub model: ModelKind,
    pub variant: DataVariant,
    pub batch: usize,
    pub rule: ScalingRule,
    pub clip: ClipMode,
    /// Use the CowClip init/dense-LR preset (vs baseline preset).
    pub cowclip_preset: bool,
    pub warmup: bool,
    /// Override embedding init sigma (None = preset).
    pub init_sigma: Option<f32>,
}

impl RunSpec {
    pub fn baseline(model: ModelKind, variant: DataVariant, batch: usize, rule: ScalingRule) -> RunSpec {
        RunSpec {
            model,
            variant,
            batch,
            rule,
            clip: ClipMode::None,
            cowclip_preset: false,
            warmup: false,
            init_sigma: None,
        }
    }

    pub fn cowclip(model: ModelKind, variant: DataVariant, batch: usize) -> RunSpec {
        RunSpec {
            model,
            variant,
            batch,
            rule: ScalingRule::CowClip,
            clip: ClipMode::CowClip,
            cowclip_preset: true,
            warmup: true,
            init_sigma: None,
        }
    }
}

/// Result of one run, ready for table assembly.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub spec: RunSpec,
    pub auc: f64,
    pub logloss: f64,
    pub report: TrainReport,
}

/// Execute one run.
pub fn run_one(ctx: &ExpContext, spec: &RunSpec) -> Result<RunResult> {
    let data = ctx.data(spec.variant)?;
    let (train, test) = (&data.0, &data.1);
    let preset = spec.variant.preset();
    let base_hypers = if spec.cowclip_preset { preset.cowclip } else { preset.baseline };
    let init_sigma = spec.init_sigma.unwrap_or(if spec.cowclip_preset {
        preset.init_sigma_cowclip
    } else {
        preset.init_sigma_baseline
    });
    let steps_per_epoch = (train.n() / spec.batch).max(1);
    let warmup_steps = if spec.warmup {
        ((steps_per_epoch as f64) * preset.warmup_epochs) as usize
    } else {
        0
    };
    let engine = ctx.engine(spec.model, spec.variant, spec.clip)?;
    let cfg = TrainConfig {
        batch: spec.batch,
        base_batch: preset.base_batch,
        base_hypers,
        rule: spec.rule,
        epochs: ctx.epochs,
        workers: ctx.workers,
        threads: 0,      // auto: experiments get the parallel engine for free
        param_shards: 0, // auto: sharded apply too
        warmup_steps,
        init_sigma,
        seed: ctx.seed,
        eval_every_epochs: 0,
        verbose: false,
    };
    let mut trainer = Trainer::new(engine, cfg)?;
    let report = trainer.train(train, test)?;
    Ok(RunResult {
        spec: spec.clone(),
        auc: report.final_auc,
        logloss: report.final_logloss,
        report,
    })
}

/// AUC formatted the paper's way (percent, 2 decimals; "div." when NaN).
pub fn fmt_auc(auc: f64) -> String {
    if auc.is_nan() {
        "diverge".into()
    } else {
        format!("{:.2}", auc * 100.0)
    }
}

pub fn fmt_logloss(ll: f64) -> String {
    if ll.is_nan() {
        "diverge".into()
    } else {
        format!("{ll:.4}")
    }
}
