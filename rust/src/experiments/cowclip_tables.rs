//! CowClip headline tables: Table 3 (prev-best vs CowClip at extreme
//! batches), Table 5 (four models on Criteo), Table 12 (four models on
//! Avazu).

use anyhow::Result;

use super::common::{fmt_auc, fmt_logloss, run_one, DataVariant, ExpContext, RunSpec};
use super::report::{Report, Table};
use crate::reference::ModelKind;
use crate::scaling::presets::{paper_label, BATCH_LADDER};
use crate::scaling::rules::ScalingRule;

/// Table 3: previous-best scaling vs CowClip at paper-1K/8K/128K.
pub fn table3(ctx: &ExpContext) -> Result<Report> {
    let batches = [64usize, 512, 8192]; // paper 1K / 8K / 128K
    let mut table = Table::new(&[
        "dataset",
        "1K prev-best",
        "1K CowClip",
        "8K prev-best",
        "8K CowClip",
        "128K prev-best",
        "128K CowClip",
    ]);
    for variant in [DataVariant::Criteo, DataVariant::CriteoSeq, DataVariant::Avazu] {
        let mut cells = vec![variant.label().to_string()];
        let n_train = ctx.data(variant)?.0.n();
        for &batch in &batches {
            if batch > n_train {
                cells.push("n/a".into());
                cells.push("n/a".into());
                continue;
            }
            // prev-best = best of {none, sqrt, linear} at this batch
            let mut best = f64::NAN;
            for rule in [ScalingRule::NoScale, ScalingRule::Sqrt, ScalingRule::Linear] {
                let r = run_one(ctx, &RunSpec::baseline(ModelKind::DeepFm, variant, batch, rule))?;
                if !r.auc.is_nan() && !(best > r.auc) {
                    best = r.auc;
                }
            }
            let cow = run_one(ctx, &RunSpec::cowclip(ModelKind::DeepFm, variant, batch))?;
            cells.push(fmt_auc(best));
            cells.push(fmt_auc(cow.auc));
        }
        table.row(cells);
    }
    let body = format!(
        "{}\n*Paper Table 3: previous rules hold at 1K, visibly lose by 8K and \
         fail/diverge at 128K; CowClip stays flat (or better) across the whole \
         span on all three datasets.*",
        table.to_markdown()
    );
    Ok(Report::new("table3", "Previous-best scaling vs CowClip (DeepFM)", body))
}

fn four_model_grid(
    ctx: &ExpContext,
    variant: DataVariant,
    id: &str,
    title: &str,
    paper_note: &str,
) -> Result<Report> {
    let n_train = ctx.data(variant)?.0.n();
    let batches: Vec<(&str, usize)> = BATCH_LADDER
        .iter()
        .filter(|&&(_, b)| b <= n_train)
        .copied()
        .collect();

    let mut header: Vec<String> = vec!["model".into(), "metric".into(), "baseline".into()];
    header.extend(batches.iter().map(|&(l, _)| l.to_string()));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr);

    for model in ModelKind::ALL {
        // baseline: no-scaling at base batch with the baseline preset
        let base = run_one(
            ctx,
            &RunSpec::baseline(model, variant, 64, ScalingRule::NoScale),
        )?;
        let mut auc_cells = vec![model.label().into(), "AUC (%)".into(), fmt_auc(base.auc)];
        let mut ll_cells = vec!["".into(), "LogLoss".into(), fmt_logloss(base.logloss)];
        for &(_, batch) in &batches {
            let r = run_one(ctx, &RunSpec::cowclip(model, variant, batch))?;
            auc_cells.push(fmt_auc(r.auc));
            ll_cells.push(fmt_logloss(r.logloss));
        }
        table.row(auc_cells);
        table.row(ll_cells);
    }
    let body = format!("{}\n*{}*", table.to_markdown(), paper_note);
    Ok(Report::new(id, title, body))
}

/// Table 5: CowClip on all four models, Criteo, full batch ladder.
pub fn table5(ctx: &ExpContext) -> Result<Report> {
    four_model_grid(
        ctx,
        DataVariant::Criteo,
        "table5",
        "CowClip across models and batch sizes, Criteo(synth)",
        "Paper Table 5: all four models hold (and slightly improve) AUC from \
         1K to 128K under CowClip — the method is model-agnostic. Expect flat \
         rows here; the ~+0.1% gain over the baseline column mirrors the \
         paper's improvement at small batch.",
    )
}

/// Table 12: CowClip on all four models, Avazu.
pub fn table12(ctx: &ExpContext) -> Result<Report> {
    four_model_grid(
        ctx,
        DataVariant::Avazu,
        "table12",
        "CowClip across models and batch sizes, Avazu(synth)",
        "Paper Table 12: same model-agnostic flatness on Avazu (paper sees a \
         small dip only at 128K).",
    )
}

/// Paper label for the largest batch that fits this context's dataset —
/// used by the CLI summary.
pub fn max_paper_batch(ctx: &ExpContext) -> Result<&'static str> {
    let n = ctx.data(DataVariant::Criteo)?.0.n();
    Ok(BATCH_LADDER
        .iter()
        .rev()
        .find(|&&(_, b)| b <= n)
        .map(|&(l, _)| l)
        .unwrap_or("1K"))
}

#[allow(unused)]
fn _label(b: usize) -> Option<&'static str> {
    paper_label(b)
}
