//! Experiment harness: one entry per table/figure of the paper.
//!
//! Every experiment takes an [`common::ExpContext`] (dataset size, epoch
//! budget, seed, runtime handle) and returns a rendered
//! [`report::Report`] that is printed and persisted under `results/`.
//! The index in DESIGN.md §6 maps each id to the paper artifact it
//! regenerates; `cowclip experiment all` runs everything.

pub mod ablation_tables;
pub mod common;
pub mod cowclip_tables;
pub mod figures;
pub mod hypers_table;
pub mod report;
pub mod scaling_tables;
pub mod timing_tables;

use anyhow::{bail, Result};

pub use common::ExpContext;
pub use report::Report;

/// All experiment ids in run order.
pub const ALL_IDS: [&str; 17] = [
    "fig1", "fig3", "fig4", "fig5", "table2", "table3", "table4", "table5", "table6",
    "table7", "hypers", "table10", "table11", "table12", "table13", "table14", "fig7_8",
];

/// Quick subset that still touches every experiment *kind*.
pub const QUICK_IDS: [&str; 7] =
    ["fig3", "fig4", "hypers", "fig1", "table2", "table7", "fig5"];

/// Run one experiment by id.
pub fn run(id: &str, ctx: &ExpContext) -> Result<Report> {
    match id {
        "fig1" => figures::fig1(ctx),
        "fig3" => figures::fig3(ctx),
        "fig4" => figures::fig4(ctx),
        "fig5" => figures::fig5(ctx),
        "fig7_8" | "fig78" => figures::fig7_8(ctx),
        "table2" => scaling_tables::table2(ctx),
        "table3" => cowclip_tables::table3(ctx),
        "table4" => scaling_tables::table4(ctx),
        "table5" => cowclip_tables::table5(ctx),
        "table6" => timing_tables::table6(ctx),
        "table7" => ablation_tables::table7(ctx),
        "table10" => scaling_tables::table10(ctx),
        "table11" => scaling_tables::table11(ctx),
        "table12" => cowclip_tables::table12(ctx),
        "table13" => timing_tables::table13(ctx),
        "table14" => ablation_tables::table14(ctx),
        "hypers" => hypers_table::hypers(ctx),
        other => bail!("unknown experiment {other:?}; known: {ALL_IDS:?}"),
    }
}
