//! Markdown table assembly + results persistence.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

/// A simple markdown table builder.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
        self
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(s, " {c:<w$} |");
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.header, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }
}

/// One experiment's rendered output.
pub struct Report {
    pub id: String,
    pub title: String,
    pub body: String,
}

impl Report {
    pub fn new(id: &str, title: &str, body: String) -> Report {
        Report { id: id.into(), title: title.into(), body }
    }

    pub fn to_markdown(&self) -> String {
        format!("## {} — {}\n\n{}\n", self.id, self.title, self.body)
    }

    /// Print to stdout and persist under `results/<id>.md`.
    pub fn emit(&self, results_dir: &Path) -> Result<()> {
        let text = self.to_markdown();
        println!("{text}");
        std::fs::create_dir_all(results_dir)?;
        std::fs::write(results_dir.join(format!("{}.md", self.id)), &text)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["rule", "1K", "8K"]);
        t.row(vec!["No Scaling".into(), "80.76".into(), "80.31".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| rule       | 1K    | 8K    |"));
        assert!(md.lines().count() == 3);
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        Table::new(&["a", "b"]).row(vec!["x".into()]);
    }
}
