//! Tables 8/9: the hyperparameter schedules every rule induces — pure
//! computation over the scaling engine, unit-testable against the paper's
//! printed values.

use anyhow::Result;

use super::report::{Report, Table};
use crate::scaling::presets::{avazu_preset, criteo_preset, BATCH_LADDER};
use crate::scaling::rules::ScalingRule;

pub fn hypers(_ctx: &super::common::ExpContext) -> Result<Report> {
    let mut body = String::new();

    // Table 8: sqrt / linear / empirical(n2-lambda) schedules
    body.push_str("**Table 8 — baseline scaling schedules (base LR/L2 = 1e-4)**\n\n");
    let base = crate::scaling::rules::HyperSet {
        lr_dense: 1e-4,
        lr_embed: 1e-4,
        l2_embed: 1e-4,
        clip_r: 1.0,
        clip_zeta: 1e-5,
        clip_t: 1.0,
    };
    let mut t8 = Table::new(&[
        "batch", "sqrt LR", "sqrt L2", "linear LR", "linear L2",
        "n2λ LR(emb)", "n2λ L2", "n2λ LR(dense)",
    ]);
    for &(label, _) in BATCH_LADDER.iter().take(4) {
        let s = match label {
            "1K" => 1.0,
            "2K" => 2.0,
            "4K" => 4.0,
            _ => 8.0,
        };
        let sq = ScalingRule::Sqrt.apply(&base, s);
        let li = ScalingRule::Linear.apply(&base, s);
        let em = ScalingRule::N2Lambda.apply(&base, s);
        t8.row(vec![
            label.into(),
            format!("{:.2e}", sq.lr_embed),
            format!("{:.2e}", sq.l2_embed),
            format!("{:.2e}", li.lr_embed),
            format!("{:.2e}", li.l2_embed),
            format!("{:.2e}", em.lr_embed),
            format!("{:.2e}", em.l2_embed),
            format!("{:.2e}", em.lr_dense),
        ]);
    }
    body.push_str(&t8.to_markdown());
    body.push('\n');

    // Table 9: CowClip schedules for both datasets
    for (name, preset) in [("Criteo", criteo_preset()), ("Avazu", avazu_preset())] {
        body.push_str(&format!(
            "**Table 9 — CowClip schedule, {name} (base: LR_emb {:.0e}, L2 {:.0e}, \
             LR_dense {:.0e}, r={}, ζ={:.0e})**\n\n",
            preset.cowclip.lr_embed,
            preset.cowclip.l2_embed,
            preset.cowclip.lr_dense,
            preset.cowclip.clip_r,
            preset.cowclip.clip_zeta,
        ));
        let mut t9 = Table::new(&["batch (paper)", "ours", "LR embed", "L2", "LR dense"]);
        for &(label, batch) in BATCH_LADDER.iter() {
            let s = batch as f64 / preset.base_batch as f64;
            let h = ScalingRule::CowClip.apply(&preset.cowclip, s);
            t9.row(vec![
                label.into(),
                batch.to_string(),
                format!("{:.2e}", h.lr_embed),
                format!("{:.2e}", h.l2_embed),
                format!("{:.2e}", h.lr_dense),
            ]);
        }
        body.push_str(&t9.to_markdown());
        body.push('\n');
    }
    body.push_str(
        "*Matches the paper's Tables 8/9 schedule shapes: sqrt scales both, \
         linear scales only LR, n²-λ pins the embedding LR and squares the L2 \
         growth, CowClip pins the embedding LR with linear L2 and sqrt dense \
         LR. (Paper cells that were hand-tuned 2x/0.5x are underlined there; \
         we print the pure rule.)*",
    );
    Ok(Report::new("hypers", "Hyperparameter schedules (Tables 8/9)", body))
}
