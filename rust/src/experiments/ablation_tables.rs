//! Ablations: Table 7 (clipping designs) and Table 14 (CowClip
//! components).

use anyhow::Result;

use super::common::{fmt_auc, fmt_logloss, run_one, DataVariant, ExpContext, RunSpec};
use super::report::{Report, Table};
use crate::clip::ClipMode;
use crate::reference::ModelKind;
use crate::scaling::rules::ScalingRule;

const ABLATION_BATCHES: [(usize, &str); 2] = [(512, "8K"), (8192, "128K")];

/// Table 7: gradient-clipping design ablation — global vs field vs
/// column granularity, fixed vs adaptive thresholds.
pub fn table7(ctx: &ExpContext) -> Result<Report> {
    let n_train = ctx.data(DataVariant::Criteo)?.0.n();
    let designs: [(&str, ClipMode); 5] = [
        ("Gradient Clipping (GC)", ClipMode::Global),
        ("Field-wise GC", ClipMode::Field),
        ("Column-wise GC", ClipMode::Column),
        ("Adaptive Field-wise GC", ClipMode::AdaField),
        ("Adaptive Column-wise GC (CowClip)", ClipMode::CowClip),
    ];
    let mut header = vec!["design".to_string()];
    for (b, label) in ABLATION_BATCHES {
        if b <= n_train {
            header.push(format!("b={label} AUC"));
            header.push("LogLoss".into());
        }
    }
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr);
    for (label, clip) in designs {
        let mut cells = vec![label.to_string()];
        for (batch, _) in ABLATION_BATCHES {
            if batch > n_train {
                continue;
            }
            let mut spec = RunSpec::cowclip(ModelKind::DeepFm, DataVariant::Criteo, batch);
            spec.clip = clip;
            let r = run_one(ctx, &spec)?;
            cells.push(fmt_auc(r.auc));
            cells.push(fmt_logloss(r.logloss));
        }
        table.row(cells);
    }
    let body = format!(
        "{}\n*Paper Table 7: finer granularity wins (column > field > global); \
         adding adaptivity helps at column level but *hurts* at field level \
         (column norms vary within a field); adaptive column-wise — CowClip — \
         is best at both batches and is the only design stable at 128K.*",
        table.to_markdown()
    );
    Ok(Report::new("table7", "Clipping-design ablation (DeepFM, Criteo)", body))
}

/// Table 14: component ablation of the CowClip recipe.
pub fn table14(ctx: &ExpContext) -> Result<Report> {
    let n_train = ctx.data(DataVariant::Criteo)?.0.n();
    let mut header = vec!["configuration".to_string()];
    for (b, label) in ABLATION_BATCHES {
        if b <= n_train {
            header.push(format!("b={label} AUC"));
            header.push("LogLoss".into());
        }
    }
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr);

    let variants: Vec<(&str, Box<dyn Fn(usize) -> RunSpec>)> = vec![
        (
            "CowClip w/ Linear Scale on Dense",
            Box::new(|b| {
                let mut s = RunSpec::cowclip(ModelKind::DeepFm, DataVariant::Criteo, b);
                s.rule = ScalingRule::Linear; // linear-scales the dense LR too
                s
            }),
        ),
        (
            "CowClip w/ Empirical (n2-lambda) Scale",
            Box::new(|b| {
                let mut s = RunSpec::cowclip(ModelKind::DeepFm, DataVariant::Criteo, b);
                s.rule = ScalingRule::N2Lambda;
                s
            }),
        ),
        (
            "CowClip w/o zeta",
            Box::new(|b| {
                let mut s = RunSpec::cowclip(ModelKind::DeepFm, DataVariant::Criteo, b);
                s.init_sigma = None;
                s.warmup = true;
                // zeta=0 removes the lower bound
                s.clip = ClipMode::CowClip;
                s.cowclip_preset = true;
                s.rule = ScalingRule::CowClip;
                s.init_sigma = Some(1e-2);
                s
            }),
        ),
        (
            "CowClip w/o warmup",
            Box::new(|b| {
                let mut s = RunSpec::cowclip(ModelKind::DeepFm, DataVariant::Criteo, b);
                s.warmup = false;
                s
            }),
        ),
        (
            "CowClip w/o large init weight",
            Box::new(|b| {
                let mut s = RunSpec::cowclip(ModelKind::DeepFm, DataVariant::Criteo, b);
                s.init_sigma = Some(1e-4); // baseline init
                s
            }),
        ),
        (
            "CowClip (full)",
            Box::new(|b| RunSpec::cowclip(ModelKind::DeepFm, DataVariant::Criteo, b)),
        ),
    ];

    for (label, mk) in &variants {
        let mut cells = vec![label.to_string()];
        for (batch, _) in ABLATION_BATCHES {
            if batch > n_train {
                continue;
            }
            let spec = mk(batch);
            // "w/o zeta" needs zeta=0 in the hypers; RunSpec has no zeta
            // knob, so thread it via a marker on the label.
            let r = if label.contains("w/o zeta") {
                run_with_zeta_zero(ctx, &spec)?
            } else {
                run_one(ctx, &spec)?
            };
            cells.push(fmt_auc(r.auc));
            cells.push(fmt_logloss(r.logloss));
        }
        table.row(cells);
    }
    let body = format!(
        "{}\n*Paper Table 14: linear-scaling the dense LR diverges; the \
         empirical (n²-λ) schedule loses at 128K; ζ and warmup matter mainly \
         at 128K; large init matters at 8K. The full recipe wins both \
         columns.*",
        table.to_markdown()
    );
    Ok(Report::new("table14", "CowClip component ablation (DeepFM, Criteo)", body))
}

/// Variant runner with the zeta lower bound removed.
fn run_with_zeta_zero(
    ctx: &ExpContext,
    spec: &RunSpec,
) -> Result<super::common::RunResult> {
    use crate::coordinator::{TrainConfig, Trainer};
    let data = ctx.data(spec.variant)?;
    let preset = spec.variant.preset();
    let mut base_hypers = preset.cowclip;
    base_hypers.clip_zeta = 0.0;
    let steps_per_epoch = (data.0.n() / spec.batch).max(1);
    let engine = ctx.engine(spec.model, spec.variant, spec.clip)?;
    let cfg = TrainConfig {
        batch: spec.batch,
        base_batch: preset.base_batch,
        base_hypers,
        rule: spec.rule,
        epochs: ctx.epochs,
        workers: ctx.workers,
        threads: 0,
        param_shards: 0,
        warmup_steps: steps_per_epoch,
        init_sigma: spec.init_sigma.unwrap_or(preset.init_sigma_cowclip),
        seed: ctx.seed,
        eval_every_epochs: 0,
        verbose: false,
    };
    let mut trainer = Trainer::new(engine, cfg)?;
    let report = trainer.train(&data.0, &data.1)?;
    Ok(super::common::RunResult {
        spec: spec.clone(),
        auc: report.final_auc,
        logloss: report.final_logloss,
        report,
    })
}
