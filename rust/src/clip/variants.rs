//! Clipping variants over the `[V, d]` embedding-gradient table.

use std::fmt;
use std::str::FromStr;

use anyhow::bail;

use crate::data::schema::Schema;
use crate::tensor::SparseRows;

/// Matches `kernels/ref.py::EPS` (guards the 0/0 norm-ratio case).
pub const EPS: f32 = 1e-12;

/// Which Table-7 clipping design to apply.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ClipMode {
    /// No clipping (scaling-rule-only baselines).
    None,
    /// Global gradient-norm clipping over the whole table ("GC").
    Global,
    /// Per-field sub-table clipping, fixed threshold.
    Field,
    /// Per-column (per-id) clipping, fixed threshold.
    Column,
    /// Adaptive field-wise: `cnt_f * max(r*||w_f||, zeta)`.
    AdaField,
    /// Adaptive column-wise — CowClip (Alg. 1).
    CowClip,
}

impl ClipMode {
    pub const ALL: [ClipMode; 6] = [
        ClipMode::None,
        ClipMode::Global,
        ClipMode::Field,
        ClipMode::Column,
        ClipMode::AdaField,
        ClipMode::CowClip,
    ];

    /// Artifact-id string (matches `python/compile/clipping.py` keys).
    pub fn as_str(&self) -> &'static str {
        match self {
            ClipMode::None => "none",
            ClipMode::Global => "global",
            ClipMode::Field => "field",
            ClipMode::Column => "column",
            ClipMode::AdaField => "adafield",
            ClipMode::CowClip => "cowclip",
        }
    }
}

impl fmt::Display for ClipMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl FromStr for ClipMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s {
            "none" => ClipMode::None,
            "global" => ClipMode::Global,
            "field" => ClipMode::Field,
            "column" => ClipMode::Column,
            "adafield" => ClipMode::AdaField,
            "cowclip" => ClipMode::CowClip,
            other => bail!("unknown clip mode {other:?}"),
        })
    }
}

/// Clipping hyperparameters (subset of the hypers vector).
#[derive(Clone, Copy, Debug)]
pub struct ClipParams {
    /// CowClip ratio `r`.
    pub r: f32,
    /// CowClip lower bound `zeta`.
    pub zeta: f32,
    /// Fixed threshold for the non-adaptive variants.
    pub clip_t: f32,
}

impl Default for ClipParams {
    fn default() -> Self {
        // Paper: r = 1, zeta in {1e-5, 1e-4} by dataset.
        ClipParams { r: 1.0, zeta: 1e-5, clip_t: 1.0 }
    }
}

#[inline]
fn norm(xs: &[f32]) -> f32 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
}

/// The L2 norm every clipping variant uses (f64 accumulation, f32
/// result). Public so the sharded apply stage can precompute the
/// whole-table norm for `Global` mode with bitwise-identical rounding.
#[inline]
pub fn grad_l2_norm(xs: &[f32]) -> f32 {
    norm(xs)
}

#[inline]
fn rescale(xs: &mut [f32], n: f32, thresh: f32) {
    let s = (thresh / (n + EPS)).min(1.0);
    if s < 1.0 {
        for x in xs {
            *x *= s;
        }
    }
}

/// Clip the `[V, d]` gradient table in place.
///
/// * `g` — gradient of the embedding table (row-major, `v_total * d`)
/// * `w` — current table values (same layout)
/// * `counts` — per-id occurrence count in the (effective) batch
pub fn clip_embedding_grads(
    mode: ClipMode,
    g: &mut [f32],
    w: &[f32],
    counts: &[f32],
    schema: &Schema,
    d: usize,
    p: &ClipParams,
) {
    let v_total = schema.total_vocab();
    debug_assert_eq!(g.len(), v_total * d);
    debug_assert_eq!(w.len(), v_total * d);
    debug_assert_eq!(counts.len(), v_total);

    match mode {
        ClipMode::None => {}
        ClipMode::Global => {
            let n = norm(g);
            rescale(g, n, p.clip_t);
        }
        ClipMode::Field => {
            for (off, vs) in schema.fields() {
                let sl = &mut g[off * d..(off + vs) * d];
                let n = norm(sl);
                rescale(sl, n, p.clip_t);
            }
        }
        ClipMode::Column => {
            for row in g.chunks_mut(d) {
                let n = norm(row);
                rescale(row, n, p.clip_t);
            }
        }
        ClipMode::AdaField => {
            for (off, vs) in schema.fields() {
                let lo = off * d;
                let hi = (off + vs) * d;
                let cnt_f: f32 = counts[off..off + vs].iter().sum();
                let wnorm = norm(&w[lo..hi]);
                let thresh = cnt_f * (p.r * wnorm).max(p.zeta);
                let sl = &mut g[lo..hi];
                let n = norm(sl);
                rescale(sl, n, thresh);
            }
        }
        ClipMode::CowClip => {
            for (i, row) in g.chunks_mut(d).enumerate() {
                let wnorm = norm(&w[i * d..(i + 1) * d]);
                let thresh = counts[i] * (p.r * wnorm).max(p.zeta);
                let n = norm(row);
                rescale(row, n, thresh);
            }
        }
    }
}

/// Sparse twin of [`clip_embedding_grads`]: clips only the touched rows
/// of the gradient, in O(touched · d) for every mode except `AdaField`
/// (whose adaptive threshold needs the *full* per-field `||w_f||`, an
/// O(V · d) read kept for exactness with the dense twin — the sharded
/// `ParamStore` path avoids it by passing maintained `Σw²` to
/// [`clip_embedding_grads_range`] directly).
///
/// Exactness vs the dense twin holds because untouched rows carry a zero
/// gradient: per-row modes (None/Column/CowClip) are no-ops on them, and
/// the aggregate modes (Global/Field/AdaField) see identical norms and
/// counts whether or not zero rows participate.
///
/// Delegates to [`clip_embedding_grads_range`] as the whole-table case
/// (`base = 0`, all fields, no maintained norms, no precomputed global
/// norm) — one implementation of the six-mode math, same pattern as
/// `LazyAdam::step_rows` forwarding to `lazy_step_rows`.
///
/// * `g` — sparse gradient rows over the `[V, d]` table
/// * `w` — current dense table values (`V * d`)
/// * `counts` — per-*stored-row* occurrence counts, aligned with `g.ids()`
pub fn clip_embedding_grads_sparse(
    mode: ClipMode,
    g: &mut SparseRows,
    w: &[f32],
    counts: &[f32],
    schema: &Schema,
    p: &ClipParams,
) {
    let d = g.d();
    debug_assert_eq!(g.n_rows(), schema.total_vocab());
    debug_assert_eq!(w.len(), schema.total_vocab() * d);
    debug_assert_eq!(counts.len(), g.nnz());
    let fields: Vec<(usize, usize)> = schema.fields().collect();
    let (ids, vals) = g.ids_vals_mut();
    clip_embedding_grads_range(mode, ids, vals, d, w, 0, counts, &fields, None, None, p);
}

/// Shard-local twin of [`clip_embedding_grads_sparse`]: clips the stored
/// rows of one row-range view `[base, base + rows)` of the table, the
/// unit the shard-owned apply stage runs per parameter shard.
///
/// Equivalence with the whole-table twin holds when shard boundaries are
/// **field-aligned** (every field fully inside one shard — the
/// `ShardPlan` invariant): per-row modes are row-local, `Field`/
/// `AdaField` aggregate within one shard's fields, and `Global` receives
/// the precomputed whole-table gradient norm so every shard rescales by
/// the same factor.
///
/// * `ids`/`vals` — the view's stored rows (global ids, packed values)
/// * `w` — the shard's weight rows (`rows * d` values starting at `base`)
/// * `counts` — per-stored-row occurrence counts aligned with `ids`
/// * `fields` — `(global_offset, vocab)` of the fields inside the range
/// * `field_sqnorms` — maintained per-field `Σw²` aligned with `fields`
///   (AdaField reads `sqrt` of these in O(1) instead of scanning the
///   field's rows); `None` falls back to the O(field · d) scan
/// * `global_norm` — precomputed whole-table ‖g‖ (`Global` mode only)
#[allow(clippy::too_many_arguments)]
pub fn clip_embedding_grads_range(
    mode: ClipMode,
    ids: &[u32],
    vals: &mut [f32],
    d: usize,
    w: &[f32],
    base: usize,
    counts: &[f32],
    fields: &[(usize, usize)],
    field_sqnorms: Option<&[f64]>,
    global_norm: Option<f32>,
    p: &ClipParams,
) {
    debug_assert_eq!(vals.len(), ids.len() * d);
    debug_assert_eq!(counts.len(), ids.len());

    match mode {
        ClipMode::None => {}
        ClipMode::Global => {
            let n = global_norm.unwrap_or_else(|| norm(vals));
            rescale(vals, n, p.clip_t);
        }
        ClipMode::Column => {
            for row in vals.chunks_mut(d) {
                let n = norm(row);
                rescale(row, n, p.clip_t);
            }
        }
        ClipMode::CowClip => {
            for (k, &id) in ids.iter().enumerate() {
                let lo = (id as usize - base) * d;
                let wnorm = norm(&w[lo..lo + d]);
                let thresh = counts[k] * (p.r * wnorm).max(p.zeta);
                let row = &mut vals[k * d..(k + 1) * d];
                let n = norm(row);
                rescale(row, n, thresh);
            }
        }
        ClipMode::Field => {
            let mut k = 0usize;
            for &(off, vs) in fields {
                let hi_id = (off + vs) as u32;
                let k0 = k;
                while k < ids.len() && ids[k] < hi_id {
                    k += 1;
                }
                if k == k0 {
                    continue;
                }
                let sl = &mut vals[k0 * d..k * d];
                let n = norm(sl);
                rescale(sl, n, p.clip_t);
            }
        }
        ClipMode::AdaField => {
            let mut k = 0usize;
            for (fi, &(off, vs)) in fields.iter().enumerate() {
                let hi_id = (off + vs) as u32;
                let k0 = k;
                while k < ids.len() && ids[k] < hi_id {
                    k += 1;
                }
                if k == k0 {
                    continue;
                }
                let cnt_f: f32 = counts[k0..k].iter().sum();
                let wnorm = match field_sqnorms {
                    Some(sq) => sq[fi].max(0.0).sqrt() as f32,
                    None => norm(&w[(off - base) * d..(off + vs - base) * d]),
                };
                let thresh = cnt_f * (p.r * wnorm).max(p.zeta);
                let sl = &mut vals[k0 * d..k * d];
                let n = norm(sl);
                rescale(sl, n, thresh);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_schema() -> Schema {
        Schema { name: "t".into(), n_dense: 0, vocab_sizes: vec![3, 2] }
    }

    fn setup(d: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let v = 5;
        let g: Vec<f32> = (0..v * d).map(|i| (i as f32 - 3.0) * 2.0).collect();
        let w: Vec<f32> = (0..v * d).map(|i| 0.1 + 0.01 * i as f32).collect();
        let counts = vec![2.0, 0.0, 1.0, 3.0, 1.0];
        (g, w, counts)
    }

    #[test]
    fn none_leaves_grads_untouched() {
        let schema = tiny_schema();
        let (mut g, w, c) = setup(4);
        let orig = g.clone();
        clip_embedding_grads(ClipMode::None, &mut g, &w, &c, &schema, 4, &ClipParams::default());
        assert_eq!(g, orig);
    }

    #[test]
    fn global_bounds_total_norm() {
        let schema = tiny_schema();
        let (mut g, w, c) = setup(4);
        let p = ClipParams { clip_t: 2.0, ..Default::default() };
        clip_embedding_grads(ClipMode::Global, &mut g, &w, &c, &schema, 4, &p);
        assert!(norm(&g) <= 2.0 + 1e-4);
    }

    #[test]
    fn field_bounds_each_field() {
        let schema = tiny_schema();
        let (mut g, w, c) = setup(4);
        let p = ClipParams { clip_t: 0.7, ..Default::default() };
        clip_embedding_grads(ClipMode::Field, &mut g, &w, &c, &schema, 4, &p);
        assert!(norm(&g[0..12]) <= 0.7 + 1e-4);
        assert!(norm(&g[12..20]) <= 0.7 + 1e-4);
    }

    #[test]
    fn column_bounds_each_row() {
        let schema = tiny_schema();
        let (mut g, w, c) = setup(4);
        let p = ClipParams { clip_t: 0.3, ..Default::default() };
        clip_embedding_grads(ClipMode::Column, &mut g, &w, &c, &schema, 4, &p);
        for row in g.chunks(4) {
            assert!(norm(row) <= 0.3 + 1e-4);
        }
    }

    #[test]
    fn cowclip_threshold_formula() {
        let schema = tiny_schema();
        let d = 2;
        let mut g = vec![10.0, 0.0, 10.0, 0.0, 0.0, 0.0, 1e-9, 0.0, 3.0, 4.0];
        let w = vec![0.3, 0.4, 0.0, 0.0, 1.0, 0.0, 0.5, 0.0, 0.06, 0.08];
        let c = vec![2.0, 1.0, 0.0, 1.0, 4.0];
        let p = ClipParams { r: 1.0, zeta: 0.05, clip_t: 0.0 };
        clip_embedding_grads(ClipMode::CowClip, &mut g, &w, &c, &schema, d, &p);
        // row0: thresh = 2 * max(0.5, 0.05) = 1.0; |g| was 10 -> scaled to 1
        assert!((norm(&g[0..2]) - 1.0).abs() < 1e-5);
        // row1: thresh = 1 * max(0, .05) = 0.05 -> 10 clipped to 0.05
        assert!((norm(&g[2..4]) - 0.05).abs() < 1e-6);
        // row2: cnt=0 -> thresh 0 -> zero grad stays zero
        assert_eq!(&g[4..6], &[0.0, 0.0]);
        // row3: tiny grad below thresh -> untouched
        assert!((g[6] - 1e-9).abs() < 1e-12);
        // row4: thresh = 4 * max(0.1, 0.05) = 0.4; |g|=5 -> 0.4
        assert!((norm(&g[8..10]) - 0.4).abs() < 1e-5);
    }

    #[test]
    fn adafield_uses_field_aggregate() {
        let schema = tiny_schema();
        let d = 1;
        let mut g = vec![6.0, 8.0, 0.0, 5.0, 12.0];
        let w = vec![1.0, 0.0, 0.0, 3.0, 4.0];
        let c = vec![1.0, 1.0, 1.0, 2.0, 0.0];
        let p = ClipParams { r: 1.0, zeta: 1e-6, clip_t: 0.0 };
        clip_embedding_grads(ClipMode::AdaField, &mut g, &w, &c, &schema, d, &p);
        // field0: cnt=3, ||w||=1 -> thresh 3; ||g||=10 -> scale 0.3
        assert!((g[0] - 1.8).abs() < 1e-5 && (g[1] - 2.4).abs() < 1e-5);
        // field1: cnt=2, ||w||=5 -> thresh 10; ||g||=13 -> scale 10/13
        assert!((norm(&g[3..5]) - 10.0).abs() < 1e-4);
    }

    #[test]
    fn sparse_twin_matches_dense_on_touched_support() {
        // rows 1 and 3 touched; the dense gradient is zero elsewhere
        let schema = tiny_schema();
        let d = 3;
        let v = schema.total_vocab();
        let ids = vec![1u32, 3];
        let sparse_vals = vec![3.0, -4.0, 0.0, 1.0, 2.0, 2.0];
        let sparse_counts = vec![2.0, 5.0];
        let w: Vec<f32> = (0..v * d).map(|i| 0.05 * (i as f32 - 4.0)).collect();
        let mut dense_counts = vec![0.0f32; v];
        dense_counts[1] = 2.0;
        dense_counts[3] = 5.0;
        for mode in ClipMode::ALL {
            let p = ClipParams { r: 1.0, zeta: 1e-3, clip_t: 0.8 };
            let mut sg = SparseRows::new(v, d, ids.clone(), sparse_vals.clone());
            let mut dg = sg.to_dense();
            clip_embedding_grads(mode, &mut dg, &w, &dense_counts, &schema, d, &p);
            clip_embedding_grads_sparse(mode, &mut sg, &w, &sparse_counts, &schema, &p);
            for (a, b) in sg.to_dense().iter().zip(&dg) {
                assert!((a - b).abs() <= 1e-6, "{mode}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn range_twin_matches_whole_table_across_field_aligned_shards() {
        // rows 0,1,4 touched; split field-aligned at row 3 into 2 shards
        let schema = tiny_schema();
        let d = 2;
        let v = schema.total_vocab();
        let ids = vec![0u32, 1, 4];
        let vals = vec![3.0f32, -4.0, 0.5, 0.5, 2.0, -2.0];
        let counts = vec![2.0f32, 1.0, 5.0];
        let w: Vec<f32> = (0..v * d).map(|i| 0.04 * (i as f32 - 3.0)).collect();
        let fields: Vec<(usize, usize)> = schema.fields().collect();
        for mode in ClipMode::ALL {
            let p = ClipParams { r: 1.0, zeta: 1e-3, clip_t: 0.6 };
            // whole-table sparse twin
            let mut whole = SparseRows::new(v, d, ids.clone(), vals.clone());
            clip_embedding_grads_sparse(mode, &mut whole, &w, &counts, &schema, &p);
            // sharded: precompute the global norm the way the store does
            let gnorm = (mode == ClipMode::Global).then(|| grad_l2_norm(&vals));
            let sqnorms: Vec<f64> = fields
                .iter()
                .map(|&(off, vs)| {
                    w[off * d..(off + vs) * d].iter().map(|&x| (x as f64) * (x as f64)).sum()
                })
                .collect();
            let mut sharded = SparseRows::new(v, d, ids.clone(), vals.clone());
            let views = sharded.range_views_mut(&[(0, 3), (3, 5)]);
            for (s, view) in views.into_iter().enumerate() {
                let fr = if s == 0 { 0..1 } else { 1..2 };
                let cnt: Vec<f32> = view.ids.iter().map(|id| counts[ids.iter().position(|x| x == id).unwrap()]).collect();
                clip_embedding_grads_range(
                    mode,
                    view.ids,
                    view.vals,
                    d,
                    &w[view.base * d..(view.base + view.rows) * d],
                    view.base,
                    &cnt,
                    &fields[fr.clone()],
                    Some(&sqnorms[fr]),
                    gnorm,
                    &p,
                );
            }
            for (a, b) in sharded.to_dense().iter().zip(whole.to_dense()) {
                assert!((a - b).abs() <= 1e-6, "{mode}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn parse_roundtrip() {
        for m in ClipMode::ALL {
            assert_eq!(m.as_str().parse::<ClipMode>().unwrap(), m);
        }
        assert!("bogus".parse::<ClipMode>().is_err());
    }
}
