//! Host-side reference implementations of every clipping strategy from
//! the paper's Table 7 ablation, mirroring `python/compile/clipping.py`.
//!
//! The production path bakes the variant into the AOT `apply` artifact;
//! these Rust twins power the no-artifact reference trainer, the parity
//! tests and the proptest invariants (norm bounds, direction
//! preservation, no-op-below-threshold).

mod variants;

pub use variants::{
    clip_embedding_grads, clip_embedding_grads_range, clip_embedding_grads_sparse,
    grad_l2_norm, ClipMode, ClipParams, EPS,
};
