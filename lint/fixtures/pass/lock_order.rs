//! Pass fixture: every function acquires `weights` before `opt` — the
//! acquisition-order graph is a straight line, no cycle.

use std::sync::{Mutex, RwLock};

pub struct Store {
    weights: RwLock<Vec<f32>>,
    opt: Mutex<Vec<f32>>,
}

impl Store {
    pub fn step(&self) {
        let w = self.weights.write();
        let o = self.opt.lock();
        drop(o);
        drop(w);
    }

    pub fn inspect(&self) -> usize {
        let w = self.weights.read();
        let o = self.opt.lock();
        drop(w);
        drop(o);
        0
    }
}
