//! Pass fixture: ordered containers and slice-ordered reductions only.

use std::collections::BTreeMap;

pub fn accumulate(rows: &BTreeMap<usize, f32>) -> f32 {
    let mut total = 0.0;
    for (_, v) in rows {
        total += *v;
    }
    total
}

pub fn slice_sum(xs: &[f32]) -> f32 {
    xs.iter().sum()
}
