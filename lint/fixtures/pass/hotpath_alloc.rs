//! Pass fixture: a hot root whose reachable call graph is
//! allocation-free. `allowed_helper` allocates but is allowlisted in
//! the test config; `cold_path` allocates but is unreachable from any
//! root. (Fixtures are lexed by the lint, never compiled.)

pub fn hot_root(dst: &mut [f32], src: &[f32]) -> f32 {
    clean_helper(dst, src);
    allowed_helper(src.len())
}

fn clean_helper(dst: &mut [f32], src: &[f32]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d += *s;
    }
}

fn allowed_helper(n: usize) -> f32 {
    let buf: Vec<usize> = (0..n).collect();
    buf.len() as f32
}

pub fn hot_with_waiver(n: usize) -> usize {
    let out: Vec<f32> = Vec::new(); // lint:allow(hotpath-alloc): empty Vec never allocates
    out.len() + n
}

pub fn cold_path(n: usize) -> Vec<f32> {
    vec![0.0; n]
}
