// Pass fixture for the unsafe-confinement rule: this file is linted
// under the relative path `reference/simd/x86.rs`, where the `unsafe`
// token is permitted (the SIMD kernel modules are the one exempt
// subtree). Never compiled — only lexed.
#![allow(unsafe_code)]

pub fn axpy(y: &mut [f32], x: &[f32], a: f32) {
    // Safety: reachable only through the vtable installed after
    // runtime feature detection.
    unsafe { axpy_inner(y, x, a) }
}

unsafe fn axpy_inner(y: &mut [f32], x: &[f32], a: f32) {
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += a * xv;
    }
}
