// Pass fixture for obs-inert: hot-path code may call the alloc-free
// recording API (span / span_rank / tracing_on) and may use handles
// that were registered at setup time, outside the hot call graph.

pub fn hot_root(xs: &mut [f32]) {
    let _span = crate::obs::span(crate::obs::Phase::Forward);
    helper(xs, 0);
}

fn helper(xs: &mut [f32], rank: usize) {
    if crate::obs::tracing_on() {
        let _s = crate::obs::span_rank(crate::obs::Phase::Clip, rank);
    }
    for x in xs.iter_mut() {
        *x += 1.0;
    }
}

// Registration happens in setup code that the hot roots never reach.
pub fn setup() -> std::sync::Arc<crate::obs::Counter> {
    crate::obs::counter("fixture.steps")
}
