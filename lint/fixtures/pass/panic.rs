//! Pass fixture: poison-tolerant locking and no unwrap / expect /
//! direct slice indexing in the request path.

use std::sync::{Mutex, PoisonError};

pub struct Queue {
    state: Mutex<Vec<u64>>,
}

impl Queue {
    pub fn take_next(&self) -> Option<u64> {
        let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        st.pop()
    }
}
