//! Fail fixture: an unwrap and a direct slice index in the request
//! path — either one can take the serve worker down on bad input.

use std::sync::Mutex;

pub struct Queue {
    q: Mutex<Vec<u64>>,
}

impl Queue {
    pub fn take_next(&self) -> u64 {
        let st = self.q.lock().unwrap();
        st[0]
    }
}
