// Fail fixture for the unsafe-confinement rule: identical shape to the
// pass fixture, but linted under `serve/helper.rs` — outside the SIMD
// subtree — so both `unsafe` tokens must be flagged. A mention of
// unsafe in a comment or "an unsafe string" must NOT be flagged: the
// rule scans tokens, and comments/strings are not identifier tokens.
pub fn fast_path(y: &mut [f32]) {
    let p = y.as_mut_ptr();
    unsafe {
        *p = 1.0;
    }
}

unsafe fn raw_write(p: *mut f32) {
    *p = 2.0;
}
