//! Fail fixture: a waiver comment without a justification is itself a
//! violation — the escape hatch must stay auditable.

pub fn helper(n: usize) -> usize {
    let out: Vec<f32> = Vec::new(); // lint:allow(hotpath-alloc)
    out.len() + n
}
