//! Fail fixture: `step` acquires weights -> opt while `rollback`
//! acquires opt -> weights — a classic deadlock-capable cycle.

use std::sync::{Mutex, RwLock};

pub struct Store {
    weights: RwLock<Vec<f32>>,
    opt: Mutex<Vec<f32>>,
}

impl Store {
    pub fn step(&self) {
        let w = self.weights.write();
        let o = self.opt.lock();
        drop(o);
        drop(w);
    }

    pub fn rollback(&self) {
        let o = self.opt.lock();
        let w = self.weights.read();
        drop(w);
        drop(o);
    }
}
