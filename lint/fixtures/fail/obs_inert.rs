// Fail fixture for obs-inert: a registry registration and a snapshot
// reachable from a hot-path root. Both allocate (name formatting,
// registry lock) and must be hoisted to setup code.

pub fn hot_root(xs: &mut [f32]) {
    let _span = crate::obs::span(crate::obs::Phase::Forward);
    helper(xs);
}

fn helper(xs: &mut [f32]) {
    // registering inside the step: flagged (transitively hot)
    let steps = crate::obs::counter("fixture.steps");
    steps.inc();
    for x in xs.iter_mut() {
        *x += 1.0;
    }
    report();
}

fn report() {
    // snapshotting inside the step: flagged
    let snap = crate::obs::snapshot_metrics();
    let _ = snap;
}
