//! Fail fixture: the root reaches an allocation two hops down the
//! call graph — the lint must report it with the `hot via` chain.

pub fn hot_root(n: usize) -> f32 {
    helper(n)
}

fn helper(n: usize) -> f32 {
    let buf = scratch(n);
    buf.iter().sum()
}

fn scratch(n: usize) -> Vec<f32> {
    vec![0.0; n]
}
