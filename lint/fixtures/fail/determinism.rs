//! Fail fixture: an unordered container in a numeric-accumulation
//! module, plus a float sum drawn from its unordered value iterator.

use std::collections::HashMap;

pub fn accumulate(rows: &HashMap<usize, f32>) -> f32 {
    rows.values().sum()
}
