//! The six rule families.
//!
//! * [`alloc`] — hot-path allocation freedom (transitive call-graph walk
//!   from the roots in `lint/hotpath.toml`).
//! * [`determinism`] — no unordered containers or unordered float sums
//!   in the numeric-accumulation modules.
//! * [`panics`] — no panicking constructs in the serve request lifecycle.
//! * [`locks`] — a consistent global lock-acquisition order (cycle-free
//!   held-while-acquiring graph).
//! * [`unsafe_conf`] — the `unsafe` token confined to the SIMD kernel
//!   modules (`reference/simd/`), mirroring the crate's
//!   `#![deny(unsafe_code)]` + scoped-allow policy.
//! * [`obs`] — observability inertness: `obs::` calls reachable from
//!   the hot-path roots must resolve into the alloc-free recording API
//!   only (`span`/`span_rank`/`tracing_on`), never registration or
//!   snapshot paths.

pub mod alloc;
pub mod determinism;
pub mod locks;
pub mod obs;
pub mod panics;
pub mod unsafe_conf;
