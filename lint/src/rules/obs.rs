//! Rule 6: observability inertness (`obs-inert`).
//!
//! Instrumentation is allowed on the hot paths precisely because the
//! recording API (`obs::span` / `obs::span_rank` / `obs::tracing_on`)
//! is allocation-free and lock-free in steady state. Everything else in
//! the `obs` module — registration (`obs::counter`), snapshots
//! (`obs::snapshot_metrics`), exporters — allocates or takes the
//! registry lock, and must stay off the hot path: register handles once
//! at setup and pass the `Arc` in.
//!
//! Starting from each root in `lint/hotpath.toml`, walk the crate-local
//! call graph (the same walk as `hotpath-alloc`) and flag any
//! `obs::<name>` call whose `name` is not on the safe list.

use std::collections::{BTreeMap, HashMap, HashSet};

use crate::functions::{calls_of, FnDef};
use crate::waivers::Waivers;
use crate::Violation;

fn dir_of(file: &str) -> &str {
    file.rfind('/').map(|p| &file[..p]).unwrap_or("")
}

/// Walk the call graph from every root and report reachable
/// non-safe-listed `obs::` calls (deduped by `(file, line, name)`).
pub fn run(
    fns: &[FnDef],
    roots: &[String],
    allow: &BTreeMap<String, String>,
    obs_safe: &[String],
    waivers: &BTreeMap<String, Waivers>,
) -> Vec<Violation> {
    let mut by_simple: HashMap<&str, Vec<usize>> = HashMap::new();
    let mut by_qual: HashMap<String, Vec<usize>> = HashMap::new();
    for (i, f) in fns.iter().enumerate() {
        if f.is_test {
            continue;
        }
        by_simple.entry(&f.name).or_default().push(i);
        by_qual.entry(f.qname()).or_default().push(i);
    }

    let resolve = |caller: &FnDef, owner: Option<&str>, name: &str| -> Vec<usize> {
        if let Some(o) = owner {
            return by_qual.get(&format!("{o}::{name}")).cloned().unwrap_or_default();
        }
        let cand = by_simple.get(name).cloned().unwrap_or_default();
        if cand.len() > 1 {
            let ckey = caller.key();
            let same_file: Vec<usize> = cand
                .iter()
                .copied()
                .filter(|&i| fns[i].file == caller.file && fns[i].key() != ckey)
                .collect();
            if !same_file.is_empty() {
                return same_file;
            }
            let cdir = dir_of(&caller.file);
            let same_dir: Vec<usize> = cand
                .iter()
                .copied()
                .filter(|&i| dir_of(&fns[i].file) == cdir && fns[i].key() != ckey)
                .collect();
            if !same_dir.is_empty() {
                return same_dir;
            }
        }
        cand
    };

    let mut violations: Vec<Violation> = Vec::new();
    let mut reported: HashSet<(String, usize, String)> = HashSet::new();
    for rootspec in roots {
        // Malformed or missing roots are already reported by the alloc
        // rule, which shares this manifest — stay quiet here.
        let Some((rfile, rq)) = rootspec.split_once(':') else {
            continue;
        };
        let Some(root) = fns
            .iter()
            .position(|f| f.file.ends_with(rfile) && f.qname() == rq && !f.is_test)
        else {
            continue;
        };
        let mut seen: HashSet<String> = HashSet::new();
        let mut stack: Vec<(usize, Vec<String>)> = vec![(root, vec![fns[root].qname()])];
        while let Some((fi, chain)) = stack.pop() {
            let f = &fns[fi];
            if !seen.insert(f.key()) {
                continue;
            }
            let w = waivers.get(&f.file);
            for call in calls_of(&f.body) {
                if call.is_macro {
                    continue;
                }
                // The inertness check itself: any obs:: call reachable
                // from a root must be on the alloc-free recording API.
                if call.owner.as_deref() == Some("obs")
                    && !obs_safe.iter().any(|s| s == &call.name)
                {
                    if w.is_some_and(|w| w.covers("obs-inert", call.line)) {
                        continue;
                    }
                    let key = (f.file.clone(), call.line, call.name.clone());
                    if !reported.insert(key) {
                        continue;
                    }
                    let via = if chain.len() == 1 {
                        String::new()
                    } else {
                        format!(" (hot via {})", chain.join(" -> "))
                    };
                    violations.push(Violation {
                        rule: "obs-inert",
                        file: f.file.clone(),
                        line: call.line,
                        msg: format!(
                            "obs::{} in hot-path fn {}{via}: only the alloc-free recording \
                             API ({}) may run here — register handles at setup",
                            call.name,
                            f.qname(),
                            obs_safe.join("/"),
                        ),
                    });
                    continue;
                }
                let qual = call.owner.as_ref().map(|o| format!("{o}::{}", call.name));
                if allow.contains_key(&call.name)
                    || qual.as_ref().is_some_and(|q| allow.contains_key(q))
                {
                    continue;
                }
                for ci in resolve(f, call.owner.as_deref(), &call.name) {
                    let callee = &fns[ci];
                    if allow.contains_key(&callee.qname()) || allow.contains_key(&callee.name) {
                        continue;
                    }
                    if !seen.contains(&callee.key()) {
                        let mut chain2 = chain.clone();
                        chain2.push(callee.qname());
                        stack.push((ci, chain2));
                    }
                }
            }
        }
    }
    violations
}
