//! Rule 1: hot-path allocation freedom.
//!
//! Starting from each root in `lint/hotpath.toml`, walk the crate-local
//! call graph and flag any forbidden allocation token reachable from
//! it. Qualified calls (`Owner::name`) resolve exactly or are treated
//! as external; unqualified calls resolve by simple name with
//! module-locality narrowing (same file, then same directory) when
//! ambiguous. Allowlisted callees stop the walk; `debug_assert*!`
//! bodies are ignored (compiled out in release).

use std::collections::{BTreeMap, HashMap, HashSet};

use crate::functions::{calls_of, debug_spans, in_spans, FnDef};
use crate::lexer::TokKind;
use crate::waivers::Waivers;
use crate::Violation;

const ALLOC_METHODS: &[&str] = &["to_vec", "clone", "collect", "cloned", "to_string", "to_owned"];
const ALLOC_MACROS: &[&str] = &["vec", "format"];

fn is_alloc_qualified(owner: &str, name: &str) -> bool {
    matches!(
        (owner, name),
        ("Vec", "new") | ("Box", "new") | ("String", "new") | ("String", "from")
    )
}

/// Forbidden allocation token sites in a function body: `(line, what)`.
pub fn alloc_sites(f: &FnDef) -> Vec<(usize, String)> {
    let body = &f.body;
    let spans = debug_spans(body);
    let mut sites: Vec<(usize, String)> = Vec::new();
    for k in 0..body.len() {
        if in_spans(&spans, k) {
            continue;
        }
        let t = &body[k];
        if t.kind != TokKind::Ident {
            continue;
        }
        let nxt = if k + 1 < body.len() { body[k + 1].text.as_str() } else { "" };
        let prev = if k > 0 { body[k - 1].text.as_str() } else { "" };
        if nxt == "!" && ALLOC_MACROS.contains(&t.text.as_str()) {
            sites.push((t.line, format!("{}!", t.text)));
        } else if nxt == "(" && prev == "." && ALLOC_METHODS.contains(&t.text.as_str()) {
            sites.push((t.line, format!(".{}()", t.text)));
        } else if t.text == "collect" && nxt == "::" {
            // turbofish form: .collect::<Vec<_>>()
            sites.push((t.line, ".collect()".to_string()));
        } else if nxt == "(" && prev == "::" && k >= 2 {
            let owner = body[k - 2].text.as_str();
            if is_alloc_qualified(owner, &t.text) {
                sites.push((t.line, format!("{owner}::{}", t.text)));
            }
        }
    }
    sites
}

fn dir_of(file: &str) -> &str {
    file.rfind('/').map(|p| &file[..p]).unwrap_or("")
}

/// Walk the call graph from every root and report reachable allocation
/// sites (deduped across roots by `(file, line, token)`).
pub fn run(
    fns: &[FnDef],
    roots: &[String],
    allow: &BTreeMap<String, String>,
    waivers: &BTreeMap<String, Waivers>,
) -> Vec<Violation> {
    let mut by_simple: HashMap<&str, Vec<usize>> = HashMap::new();
    let mut by_qual: HashMap<String, Vec<usize>> = HashMap::new();
    for (i, f) in fns.iter().enumerate() {
        if f.is_test {
            continue;
        }
        by_simple.entry(&f.name).or_default().push(i);
        by_qual.entry(f.qname()).or_default().push(i);
    }

    let resolve = |caller: &FnDef, owner: Option<&str>, name: &str| -> Vec<usize> {
        if let Some(o) = owner {
            // Qualified call: exact match or external (std / foreign
            // crate) — no simple-name fallback.
            return by_qual.get(&format!("{o}::{name}")).cloned().unwrap_or_default();
        }
        let cand = by_simple.get(name).cloned().unwrap_or_default();
        if cand.len() > 1 {
            // Module-locality narrowing: same-file candidates (other
            // than the caller itself) first, then same-directory ones;
            // otherwise walk every candidate (conservative).
            let ckey = caller.key();
            let same_file: Vec<usize> = cand
                .iter()
                .copied()
                .filter(|&i| fns[i].file == caller.file && fns[i].key() != ckey)
                .collect();
            if !same_file.is_empty() {
                return same_file;
            }
            let cdir = dir_of(&caller.file);
            let same_dir: Vec<usize> = cand
                .iter()
                .copied()
                .filter(|&i| dir_of(&fns[i].file) == cdir && fns[i].key() != ckey)
                .collect();
            if !same_dir.is_empty() {
                return same_dir;
            }
        }
        cand
    };

    let mut violations: Vec<Violation> = Vec::new();
    let mut reported: HashSet<(String, usize, String)> = HashSet::new();
    for rootspec in roots {
        let Some((rfile, rq)) = rootspec.split_once(':') else {
            violations.push(Violation {
                rule: "hotpath-alloc",
                file: rootspec.clone(),
                line: 0,
                msg: format!("malformed root spec {rootspec:?} (want file-suffix:qualified-name)"),
            });
            continue;
        };
        let Some(root) = fns
            .iter()
            .position(|f| f.file.ends_with(rfile) && f.qname() == rq && !f.is_test)
        else {
            violations.push(Violation {
                rule: "hotpath-alloc",
                file: rfile.to_string(),
                line: 0,
                msg: format!("root {rootspec} not found in tree"),
            });
            continue;
        };
        let mut seen: HashSet<String> = HashSet::new();
        let mut stack: Vec<(usize, Vec<String>)> = vec![(root, vec![fns[root].qname()])];
        while let Some((fi, chain)) = stack.pop() {
            let f = &fns[fi];
            if !seen.insert(f.key()) {
                continue;
            }
            let w = waivers.get(&f.file);
            for (line, what) in alloc_sites(f) {
                if w.is_some_and(|w| w.covers("hotpath-alloc", line)) {
                    continue;
                }
                let key = (f.file.clone(), line, what.clone());
                if !reported.insert(key) {
                    continue;
                }
                let via = if chain.len() == 1 {
                    String::new()
                } else {
                    format!(" (hot via {})", chain.join(" -> "))
                };
                violations.push(Violation {
                    rule: "hotpath-alloc",
                    file: f.file.clone(),
                    line,
                    msg: format!("{what} in hot-path fn {}{via}", f.qname()),
                });
            }
            for call in calls_of(&f.body) {
                if call.is_macro {
                    continue;
                }
                let qual = call.owner.as_ref().map(|o| format!("{o}::{}", call.name));
                if allow.contains_key(&call.name)
                    || qual.as_ref().is_some_and(|q| allow.contains_key(q))
                {
                    continue;
                }
                for ci in resolve(f, call.owner.as_deref(), &call.name) {
                    let callee = &fns[ci];
                    if allow.contains_key(&callee.qname()) || allow.contains_key(&callee.name) {
                        continue;
                    }
                    if !seen.contains(&callee.key()) {
                        let mut chain2 = chain.clone();
                        chain2.push(callee.qname());
                        stack.push((ci, chain2));
                    }
                }
            }
        }
    }
    violations
}
