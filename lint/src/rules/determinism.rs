//! Rule 2: determinism hygiene in the numeric-accumulation modules.
//!
//! `HashMap`/`HashSet` iteration order varies run to run, and floating
//! point addition is not associative — an unordered reduction there
//! silently breaks the repo's bit-exact parity contracts. Flag any
//! unordered container in the listed modules, plus float sums drawn
//! directly from `.values()` / `.keys()` iterators anywhere they
//! appear. Test code is exempt.

use std::collections::BTreeMap;

use crate::functions::FnDef;
use crate::lexer::{Tok, TokKind};
use crate::waivers::Waivers;
use crate::Violation;

pub fn run(
    fns: &[FnDef],
    file_toks: &[(String, Vec<Tok>)],
    det_dirs: &[String],
    waivers: &BTreeMap<String, Waivers>,
) -> Vec<Violation> {
    let mut violations: Vec<Violation> = Vec::new();
    for (file, toks) in file_toks {
        if !det_dirs.iter().any(|d| file.contains(d.as_str())) {
            continue;
        }
        let w = waivers.get(file);
        // line ranges of test fns in this file (their bodies are exempt)
        let test_ranges: Vec<(usize, usize)> = fns
            .iter()
            .filter(|f| f.file == *file && f.is_test && !f.body.is_empty())
            .map(|f| (f.body[0].line, f.body[f.body.len() - 1].line))
            .collect();
        let in_test = |line: usize| test_ranges.iter().any(|&(a, b)| a <= line && line <= b);
        for (k, t) in toks.iter().enumerate() {
            if t.kind != TokKind::Ident {
                continue;
            }
            if t.text == "HashMap" || t.text == "HashSet" {
                if in_test(t.line) || w.is_some_and(|w| w.covers("determinism", t.line)) {
                    continue;
                }
                violations.push(Violation {
                    rule: "determinism",
                    file: file.clone(),
                    line: t.line,
                    msg: format!("{} in numeric-accumulation module (unordered iteration)", t.text),
                });
            }
            if (t.text == "values" || t.text == "keys")
                && k + 1 < toks.len()
                && toks[k + 1].text == "("
            {
                let window = &toks[k..toks.len().min(k + 14)];
                if window.iter().any(|t| t.text == "sum") {
                    if in_test(t.line) || w.is_some_and(|w| w.covers("determinism", t.line)) {
                        continue;
                    }
                    violations.push(Violation {
                        rule: "determinism",
                        file: file.clone(),
                        line: t.line,
                        msg: "float sum over unordered iterator".to_string(),
                    });
                }
            }
        }
    }
    violations
}
