//! Rule 3: panic freedom in the serve request lifecycle.
//!
//! A panic in the scoring path takes down the worker (or poisons a
//! shared lock) on a single bad request. In the listed files, flag
//! panicking macros, `.unwrap()` / `.expect()`, and — in the files that
//! handle raw request bytes — direct slice indexing (`x[i]`, which
//! panics out of bounds). Poison-tolerant lock recovery
//! (`unwrap_or_else(PoisonError::into_inner)`) passes because the
//! matcher requires the exact `unwrap` identifier. Test code is exempt.

use std::collections::BTreeMap;

use crate::functions::{is_keyword, FnDef};
use crate::lexer::TokKind;
use crate::waivers::Waivers;
use crate::Violation;

const PANIC_MACROS: &[&str] =
    &["panic", "unreachable", "todo", "unimplemented", "assert", "assert_eq", "assert_ne"];
const PANIC_METHODS: &[&str] = &["unwrap", "expect"];

pub fn run(
    fns: &[FnDef],
    panic_files: &[String],
    index_files: &[String],
    waivers: &BTreeMap<String, Waivers>,
) -> Vec<Violation> {
    let mut violations: Vec<Violation> = Vec::new();
    for f in fns {
        if f.is_test || !panic_files.iter().any(|p| f.file.ends_with(p.as_str())) {
            continue;
        }
        let w = waivers.get(&f.file);
        let waived = |line: usize| w.is_some_and(|w| w.covers("panic", line));
        let index_file = index_files.iter().any(|p| f.file.ends_with(p.as_str()));
        let body = &f.body;
        for k in 0..body.len() {
            let t = &body[k];
            let nxt = if k + 1 < body.len() { body[k + 1].text.as_str() } else { "" };
            let prev = if k > 0 { body[k - 1].text.as_str() } else { "" };
            if t.kind == TokKind::Ident && nxt == "!" && PANIC_MACROS.contains(&t.text.as_str()) {
                if waived(t.line) {
                    continue;
                }
                violations.push(Violation {
                    rule: "panic",
                    file: f.file.clone(),
                    line: t.line,
                    msg: format!("{}! in request lifecycle fn {}", t.text, f.qname()),
                });
            }
            if t.kind == TokKind::Ident
                && nxt == "("
                && prev == "."
                && PANIC_METHODS.contains(&t.text.as_str())
            {
                if waived(t.line) {
                    continue;
                }
                violations.push(Violation {
                    rule: "panic",
                    file: f.file.clone(),
                    line: t.line,
                    msg: format!(".{}() in request lifecycle fn {}", t.text, f.qname()),
                });
            }
            if t.text == "[" && index_file {
                // `x[i]` / `f(..)[i]` / `x[i][j]` — but not array
                // literals, attributes, or slice patterns
                let (pk, pt) = if k > 0 {
                    (body[k - 1].kind, body[k - 1].text.as_str())
                } else {
                    (TokKind::Punct, "")
                };
                if (pk == TokKind::Ident && !is_keyword(pt)) || pt == ")" || pt == "]" {
                    if waived(t.line) {
                        continue;
                    }
                    violations.push(Violation {
                        rule: "panic",
                        file: f.file.clone(),
                        line: t.line,
                        msg: format!("slice index (may panic) in request lifecycle fn {}", f.qname()),
                    });
                }
            }
        }
    }
    violations
}
