//! Rule 4: lock-acquisition order discipline.
//!
//! Extracts "held while acquiring" edges between the repo's known
//! locks (ParamStore weights/opt, StepPool jobs, the serve queue
//! internals) by scanning each function body with brace-depth guard
//! liveness: a `let`-bound guard lives until its block closes, an
//! unbound temporary dies at the end of its statement. A cycle in the
//! resulting graph is a potential deadlock and fails the lint.

use std::collections::{BTreeMap, BTreeSet, HashMap};

use crate::functions::FnDef;
use crate::lexer::TokKind;
use crate::waivers::Waivers;
use crate::Violation;

/// One known lock: where it lives, the receiver identifier it is
/// acquired through, the acquisition methods, and its canonical name
/// in the order graph.
pub struct LockSpec {
    /// Substring match against the file path (e.g. `"coordinator/"`).
    pub file_pat: &'static str,
    /// Receiver identifier at the call site (`self.<recv>.lock()`).
    pub recv: &'static str,
    pub methods: &'static [&'static str],
    /// Canonical lock name; distinct receivers may alias one lock.
    pub canon: &'static str,
}

type Edges = BTreeMap<String, BTreeSet<String>>;
type Sites = HashMap<(String, String), (String, usize, String)>;

pub fn run(
    fns: &[FnDef],
    locks: &[LockSpec],
    waivers: &BTreeMap<String, Waivers>,
) -> Vec<Violation> {
    let mut edges: Edges = BTreeMap::new();
    let mut sites: Sites = HashMap::new();
    for f in fns {
        if f.is_test {
            continue;
        }
        // (canonical name, Some(bind depth) if let-bound)
        let mut held: Vec<(String, Option<i64>)> = Vec::new();
        let mut depth = 0i64;
        let mut stmt_has_let = false;
        let body = &f.body;
        for k in 0..body.len() {
            let t = &body[k];
            match t.text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    held.retain(|h| match h.1 {
                        Some(bind) => bind <= depth,
                        None => true,
                    });
                }
                ";" => {
                    // unbound guard temporaries die at statement end
                    held.retain(|h| h.1.is_some());
                    stmt_has_let = false;
                }
                "let" => stmt_has_let = true,
                _ => {}
            }
            let is_acquire = t.kind == TokKind::Ident
                && matches!(t.text.as_str(), "lock" | "read" | "write")
                && k + 1 < body.len()
                && body[k + 1].text == "("
                && k > 0
                && body[k - 1].text == ".";
            if !is_acquire {
                continue;
            }
            let recv = if k >= 2 && body[k - 2].kind == TokKind::Ident {
                Some(body[k - 2].text.as_str())
            } else {
                None
            };
            let canon = locks.iter().find_map(|l| {
                let hit = f.file.contains(l.file_pat)
                    && recv == Some(l.recv)
                    && l.methods.contains(&t.text.as_str());
                if hit {
                    Some(l.canon)
                } else {
                    None
                }
            });
            let Some(canon) = canon else {
                continue;
            };
            for (h, _) in &held {
                if h != canon {
                    edges.entry(h.clone()).or_default().insert(canon.to_string());
                    sites.insert(
                        (h.clone(), canon.to_string()),
                        (f.file.clone(), t.line, f.qname()),
                    );
                }
            }
            held.push((canon.to_string(), if stmt_has_let { Some(depth) } else { None }));
        }
    }

    // DFS cycle detection over the edge graph (BTreeMap: deterministic)
    let mut violations: Vec<Violation> = Vec::new();
    let mut color: HashMap<String, u8> = HashMap::new();
    let nodes: Vec<String> = edges.keys().cloned().collect();
    for u in &nodes {
        if color.get(u).copied().unwrap_or(0) == 0 {
            let mut path = vec![u.clone()];
            dfs(u, &mut path, &mut color, &edges, &sites, waivers, &mut violations);
        }
    }
    violations
}

fn dfs(
    u: &str,
    path: &mut Vec<String>,
    color: &mut HashMap<String, u8>,
    edges: &Edges,
    sites: &Sites,
    waivers: &BTreeMap<String, Waivers>,
    out: &mut Vec<Violation>,
) {
    color.insert(u.to_string(), 1);
    if let Some(vs) = edges.get(u) {
        for v in vs {
            match color.get(v).copied().unwrap_or(0) {
                1 => {
                    let cyc: Vec<String> = match path.iter().position(|x| x == v) {
                        Some(p) => {
                            let mut c = path[p..].to_vec();
                            c.push(v.clone());
                            c
                        }
                        None => vec![u.to_string(), v.clone()],
                    };
                    if let Some((file, line, q)) = sites.get(&(u.to_string(), v.clone())) {
                        if waivers.get(file).is_some_and(|w| w.covers("lock-order", *line)) {
                            continue;
                        }
                        out.push(Violation {
                            rule: "lock-order",
                            file: file.clone(),
                            line: *line,
                            msg: format!(
                                "lock acquisition cycle: {} (edge {u} -> {v} in {q})",
                                cyc.join(" -> ")
                            ),
                        });
                    }
                }
                0 => {
                    path.push(v.clone());
                    dfs(v, path, color, edges, sites, waivers, out);
                    path.pop();
                }
                _ => {}
            }
        }
    }
    color.insert(u.to_string(), 2);
}
