//! Rule 5: `unsafe` confinement.
//!
//! The crate root compiles under `#![deny(unsafe_code)]`; the SIMD
//! microkernel modules opt back in with a scoped `#![allow(unsafe_code)]`
//! because `core::arch` intrinsics and `#[target_feature]` functions
//! require it. This rule is the second fence around that opt-in: the
//! `unsafe` token may appear **only** in files under the configured
//! directories (`reference/simd/` for this repo). Everywhere else —
//! including test modules, matching the compiler-level deny — any
//! occurrence is a violation. The scan runs over the raw token stream,
//! so `unsafe fn`, `unsafe {}` blocks, `unsafe impl` and `unsafe trait`
//! are all caught; comments and string literals are not tokens and
//! cannot trip it.

use std::collections::BTreeMap;

use crate::lexer::{Tok, TokKind};
use crate::waivers::Waivers;
use crate::Violation;

pub fn run(
    file_toks: &[(String, Vec<Tok>)],
    unsafe_dirs: &[String],
    waivers: &BTreeMap<String, Waivers>,
) -> Vec<Violation> {
    let mut violations: Vec<Violation> = Vec::new();
    for (rel, toks) in file_toks {
        if unsafe_dirs.iter().any(|d| rel.contains(d.as_str())) {
            continue;
        }
        let w = waivers.get(rel);
        for t in toks {
            if t.kind == TokKind::Ident && t.text == "unsafe" {
                if w.is_some_and(|w| w.covers("unsafe-confinement", t.line)) {
                    continue;
                }
                violations.push(Violation {
                    rule: "unsafe-confinement",
                    file: rel.clone(),
                    line: t.line,
                    msg: format!(
                        "`unsafe` outside the SIMD kernel modules ({})",
                        if unsafe_dirs.is_empty() {
                            "no directory is exempt".to_string()
                        } else {
                            format!("only {} may use it", unsafe_dirs.join(", "))
                        }
                    ),
                });
            }
        }
    }
    violations
}
