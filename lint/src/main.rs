//! CLI entry point: lint the repo's `rust/src` tree against the policy
//! in [`cowclip_lint::Config::repo_policy`] plus `lint/hotpath.toml`.
//! Exit code 0 iff the tree is violation-free.

use std::path::Path;
use std::process::ExitCode;

use cowclip_lint::Config;

fn main() -> ExitCode {
    let manifest_dir = Path::new(env!("CARGO_MANIFEST_DIR"));
    let Some(repo_root) = manifest_dir.parent() else {
        eprintln!("cowclip-lint: cannot locate the repo root");
        return ExitCode::FAILURE;
    };
    let mut cfg = Config::repo_policy();
    if let Err(e) = cfg.load_manifest(&manifest_dir.join("hotpath.toml")) {
        eprintln!("cowclip-lint: {e}");
        return ExitCode::FAILURE;
    }
    let src_root = repo_root.join("rust").join("src");
    let violations = match cowclip_lint::lint_dir(&src_root, &cfg) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("cowclip-lint: {}: {e}", src_root.display());
            return ExitCode::FAILURE;
        }
    };
    if violations.is_empty() {
        println!(
            "cowclip-lint: rust/src is clean ({} hot-path roots, 6 rule families)",
            cfg.roots.len()
        );
        return ExitCode::SUCCESS;
    }
    for v in &violations {
        println!("{v}");
    }
    eprintln!("cowclip-lint: {} violation(s)", violations.len());
    ExitCode::FAILURE
}
