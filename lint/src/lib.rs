//! `cowclip-lint` — repo-invariant static analysis for the cowclip crate.
//!
//! The training and serving hot paths make promises an ordinary test
//! suite can't police: allocation-free steady state, bit-exact
//! determinism, panic-free request handling, and a consistent lock
//! acquisition order. This crate enforces them structurally, as a
//! blocking CI step, by lexing `rust/src/**` and running six rule
//! families over the token streams:
//!
//! 1. **hotpath-alloc** — functions registered in `lint/hotpath.toml`
//!    must not reach a forbidden allocation token through the
//!    crate-local call graph.
//! 2. **determinism** — no unordered containers or unordered float
//!    sums in the numeric-accumulation modules.
//! 3. **panic** — no panicking constructs in the serve request
//!    lifecycle files.
//! 4. **lock-order** — the "held while acquiring" graph over the
//!    repo's known locks must stay cycle-free.
//! 5. **unsafe-confinement** — the `unsafe` token may appear only in
//!    the SIMD kernel modules (`reference/simd/`).
//! 6. **obs-inert** — `obs::` calls reachable from the hot-path roots
//!    must resolve into the alloc-free recording API only
//!    (`span`/`span_rank`/`tracing_on`); registration and snapshot
//!    calls belong in setup code.
//!
//! Line-level escape hatch: `// lint:allow(<rule-id>): <justification>`
//! on (or just above) the offending line. The justification is
//! mandatory; an empty one is itself a violation (rule `waiver`).
//!
//! Deliberately dependency-free: a hand-rolled lexer plus token-level
//! function/call extraction is exactly the granularity these rules
//! need, and the repo builds offline.

pub mod functions;
pub mod lexer;
pub mod manifest;
pub mod rules;
pub mod waivers;

use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use rules::locks::LockSpec;

/// One rule violation, renderable as `file:line: [rule] message`.
#[derive(Clone, Debug)]
pub struct Violation {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// What the lint enforces: hot-path roots and allowlist (from the
/// manifest) plus the repo's module policy (which dirs must be
/// deterministic, which files must not panic, which locks exist).
pub struct Config {
    /// Hot-path roots, `file-suffix:qualified-name`.
    pub roots: Vec<String>,
    /// Call-graph allowlist: callee name (or `Type::name`) -> why.
    pub allow: BTreeMap<String, String>,
    /// Path substrings of the determinism-critical modules.
    pub det_dirs: Vec<String>,
    /// Path suffixes of the panic-free request lifecycle files.
    pub panic_files: Vec<String>,
    /// Subset of `panic_files` where slice indexing is also banned.
    pub index_files: Vec<String>,
    /// Path substrings of the only modules allowed to use `unsafe`.
    pub unsafe_dirs: Vec<String>,
    /// `obs::` function names the hot path may call (the alloc-free
    /// recording API); any other `obs::` call reachable from a root is
    /// an `obs-inert` violation.
    pub obs_safe: Vec<String>,
    /// The repo's known locks, for acquisition-order extraction.
    pub locks: Vec<LockSpec>,
}

impl Config {
    /// The cowclip repo's policy. Roots and allowlist start empty;
    /// load them from `lint/hotpath.toml` via [`Config::load_manifest`].
    pub fn repo_policy() -> Config {
        let s = |xs: &[&str]| xs.iter().map(|x| x.to_string()).collect::<Vec<String>>();
        Config {
            roots: Vec::new(),
            allow: BTreeMap::new(),
            det_dirs: s(&["coordinator/", "clip/", "optim/", "reference/", "wire/"]),
            // The serve request lifecycle plus the distributed worker /
            // transport lifecycle: a panicking decode or socket path
            // would take down a whole training run (or leave peers
            // hanging until their deadline), so these surface errors.
            panic_files: s(&[
                "serve/queue.rs",
                "serve/request.rs",
                "serve/model.rs",
                "coordinator/transport.rs",
                "coordinator/dist.rs",
                "coordinator/chaos.rs",
                "wire/frame.rs",
                "wire/codec.rs",
                "wire/link.rs",
            ]),
            index_files: s(&[
                "serve/queue.rs",
                "serve/request.rs",
                "wire/frame.rs",
                "wire/link.rs",
            ]),
            unsafe_dirs: s(&["reference/simd/"]),
            obs_safe: s(&["span", "span_rank", "tracing_on"]),
            locks: vec![
                LockSpec {
                    file_pat: "model/store.rs",
                    recv: "weights",
                    methods: &["read", "write"],
                    canon: "ParamStore.weights",
                },
                LockSpec {
                    file_pat: "model/store.rs",
                    recv: "opt",
                    methods: &["lock"],
                    canon: "ParamStore.opt",
                },
                LockSpec {
                    file_pat: "coordinator/",
                    recv: "params",
                    methods: &["read", "write"],
                    canon: "ParamStore.weights",
                },
                LockSpec {
                    file_pat: "coordinator/",
                    recv: "store",
                    methods: &["read", "write"],
                    canon: "ParamStore.weights",
                },
                LockSpec {
                    file_pat: "coordinator/pool.rs",
                    recv: "rx",
                    methods: &["lock"],
                    canon: "StepPool.jobs",
                },
                LockSpec {
                    file_pat: "serve/queue.rs",
                    recv: "q",
                    methods: &["lock"],
                    canon: "serve.queue",
                },
                LockSpec {
                    file_pat: "serve/queue.rs",
                    recv: "counters",
                    methods: &["lock"],
                    canon: "serve.counters",
                },
                LockSpec {
                    file_pat: "serve/queue.rs",
                    recv: "error",
                    methods: &["lock"],
                    canon: "serve.error",
                },
                LockSpec {
                    file_pat: "cli/commands.rs",
                    recv: "children",
                    methods: &["lock"],
                    canon: "Supervisor.children",
                },
            ],
        }
    }

    /// Load hot-path roots and allowlist from `hotpath.toml`.
    pub fn load_manifest(&mut self, path: &Path) -> Result<(), String> {
        let src =
            fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        let (roots, allow) = manifest::parse_manifest(&src)?;
        self.roots = roots;
        self.allow = allow;
        Ok(())
    }
}

/// Lint a set of `(relative path, source)` pairs (one crate's worth of
/// files) and return every violation, sorted by `(rule, file, line)`.
pub fn lint_sources(files: &[(String, String)], cfg: &Config) -> Vec<Violation> {
    let mut all_fns: Vec<functions::FnDef> = Vec::new();
    let mut file_toks: Vec<(String, Vec<lexer::Tok>)> = Vec::new();
    let mut waivers_by_file: BTreeMap<String, waivers::Waivers> = BTreeMap::new();
    let mut violations: Vec<Violation> = Vec::new();
    for (rel, src) in files {
        let lexed = lexer::tokenize(src);
        all_fns.extend(functions::extract_functions(rel, &lexed.toks));
        let (w, bad) = waivers::parse(&lexed.comments);
        for (line, rule) in bad {
            violations.push(Violation {
                rule: "waiver",
                file: rel.clone(),
                line,
                msg: format!("lint:allow({rule}) without a justification"),
            });
        }
        waivers_by_file.insert(rel.clone(), w);
        file_toks.push((rel.clone(), lexed.toks));
    }
    violations.extend(rules::alloc::run(&all_fns, &cfg.roots, &cfg.allow, &waivers_by_file));
    violations.extend(rules::determinism::run(
        &all_fns,
        &file_toks,
        &cfg.det_dirs,
        &waivers_by_file,
    ));
    violations.extend(rules::panics::run(
        &all_fns,
        &cfg.panic_files,
        &cfg.index_files,
        &waivers_by_file,
    ));
    violations.extend(rules::locks::run(&all_fns, &cfg.locks, &waivers_by_file));
    violations.extend(rules::unsafe_conf::run(&file_toks, &cfg.unsafe_dirs, &waivers_by_file));
    violations.extend(rules::obs::run(
        &all_fns,
        &cfg.roots,
        &cfg.allow,
        &cfg.obs_safe,
        &waivers_by_file,
    ));
    violations.sort_by(|a, b| (a.rule, &a.file, a.line).cmp(&(b.rule, &b.file, b.line)));
    violations
}

/// Lint every `.rs` file under `src_root` (recursively, sorted paths,
/// `/`-normalized relative names).
pub fn lint_dir(src_root: &Path, cfg: &Config) -> io::Result<Vec<Violation>> {
    let mut paths: Vec<PathBuf> = Vec::new();
    collect_rs(src_root, &mut paths)?;
    paths.sort();
    let mut files: Vec<(String, String)> = Vec::new();
    for p in &paths {
        let rel = p
            .strip_prefix(src_root)
            .unwrap_or(p)
            .to_string_lossy()
            .replace('\\', "/");
        files.push((rel, fs::read_to_string(p)?));
    }
    Ok(lint_sources(&files, cfg))
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}
