//! A minimal Rust lexer: just enough token structure for the lint's
//! rule families — identifiers, punctuation, literals, and line
//! numbers, with comments captured separately (waiver comments live
//! there). Handles the lexical constructs that would otherwise corrupt
//! a token scan: nested block comments, raw strings (`r#"..."#`),
//! string escapes, and the char-literal vs lifetime ambiguity.

/// Token class. The lint only branches on `Ident` vs everything else;
/// the rest exist so the scan can skip literals safely.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Num,
    Str,
    Char,
    Lifetime,
    Punct,
}

/// One token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
}

/// Lexer output: the token stream plus line comments (for waivers).
pub struct Lexed {
    pub toks: Vec<Tok>,
    /// `(line, text)` for every `//` comment, in file order.
    pub comments: Vec<(usize, String)>,
}

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Tokenize Rust source. Unknown bytes degrade to single-char `Punct`
/// tokens — the lint only needs the structure around identifiers.
pub fn tokenize(src: &str) -> Lexed {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut comments: Vec<(usize, String)> = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        // line comment (captured: waivers live here)
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let mut j = i;
            while j < n && cs[j] != '\n' {
                j += 1;
            }
            comments.push((line, cs[i..j].iter().collect()));
            i = j;
            continue;
        }
        // block comment (nested)
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let mut depth = 1i64;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if cs[j] == '/' && j + 1 < n && cs[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if cs[j] == '*' && j + 1 < n && cs[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    if cs[j] == '\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            i = j;
            continue;
        }
        // raw string: r"..." / r#"..."# / br#"..."#
        {
            let mut k = i;
            if cs[k] == 'b' && k + 1 < n && cs[k + 1] == 'r' {
                k += 1;
            }
            if cs[k] == 'r' {
                let mut h = k + 1;
                while h < n && cs[h] == '#' {
                    h += 1;
                }
                if h < n && cs[h] == '"' {
                    let hashes = h - (k + 1);
                    let start_line = line;
                    let mut j = h + 1;
                    while j < n {
                        if cs[j] == '\n' {
                            line += 1;
                        }
                        if cs[j] == '"' {
                            let mut m = 0usize;
                            while m < hashes && j + 1 + m < n && cs[j + 1 + m] == '#' {
                                m += 1;
                            }
                            if m == hashes {
                                j += 1 + hashes;
                                break;
                            }
                        }
                        j += 1;
                    }
                    let j = j.min(n);
                    toks.push(Tok {
                        kind: TokKind::Str,
                        text: cs[i..j].iter().collect(),
                        line: start_line,
                    });
                    i = j;
                    continue;
                }
            }
        }
        // plain / byte string with escapes
        if c == '"' || (c == 'b' && i + 1 < n && cs[i + 1] == '"') {
            let start_line = line;
            let mut j = if c == 'b' { i + 2 } else { i + 1 };
            while j < n {
                if cs[j] == '\\' {
                    j += 2;
                    continue;
                }
                if cs[j] == '"' {
                    break;
                }
                if cs[j] == '\n' {
                    line += 1;
                }
                j += 1;
            }
            let j = (j + 1).min(n);
            toks.push(Tok {
                kind: TokKind::Str,
                text: cs[i..j].iter().collect(),
                line: start_line,
            });
            i = j;
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            let next_is_ident = i + 1 < n && is_ident_start(cs[i + 1]);
            let closes_as_char = i + 2 < n && cs[i + 2] == '\'';
            if next_is_ident && !closes_as_char {
                let mut j = i + 1;
                while j < n && is_ident_cont(cs[j]) {
                    j += 1;
                }
                toks.push(Tok { kind: TokKind::Lifetime, text: cs[i..j].iter().collect(), line });
                i = j;
                continue;
            }
            let mut j = i + 1;
            while j < n {
                if cs[j] == '\\' {
                    j += 2;
                    continue;
                }
                if cs[j] == '\'' {
                    break;
                }
                j += 1;
            }
            let j = (j + 1).min(n);
            toks.push(Tok { kind: TokKind::Char, text: cs[i..j].iter().collect(), line });
            i = j;
            continue;
        }
        if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident_cont(cs[j]) {
                j += 1;
            }
            toks.push(Tok { kind: TokKind::Ident, text: cs[i..j].iter().collect(), line });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n {
                let ch = cs[j];
                let take = is_ident_cont(ch)
                    || (ch == '.' && j + 1 < n && cs[j + 1].is_ascii_digit());
                if !take {
                    break;
                }
                j += 1;
            }
            toks.push(Tok { kind: TokKind::Num, text: cs[i..j].iter().collect(), line });
            i = j;
            continue;
        }
        // multi-char punctuation the scans rely on: `::`, `->`, `=>`
        if c == ':' && i + 1 < n && cs[i + 1] == ':' {
            toks.push(Tok { kind: TokKind::Punct, text: "::".to_string(), line });
            i += 2;
            continue;
        }
        if (c == '-' || c == '=') && i + 1 < n && cs[i + 1] == '>' {
            toks.push(Tok { kind: TokKind::Punct, text: cs[i..i + 2].iter().collect(), line });
            i += 2;
            continue;
        }
        toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }
    Lexed { toks, comments }
}
