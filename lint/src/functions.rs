//! Function extraction and call-site discovery over the token stream.
//!
//! Tracks `impl` blocks (so methods get `Type::name` qualified names),
//! `mod` nesting, and test regions (`#[cfg(test)]` modules, `#[test]`
//! functions) — test code is exempt from every rule family.

use crate::lexer::{Tok, TokKind};

/// Rust keywords the scans must never mistake for a call or type name.
pub const KEYWORDS: &[&str] = &[
    "if", "while", "match", "for", "return", "loop", "else", "in", "let", "mut", "fn", "pub",
    "impl", "use", "mod", "struct", "enum", "trait", "where", "as", "move", "ref", "unsafe",
    "const", "static", "crate", "super", "self", "Self", "dyn", "type", "break", "continue",
];

pub fn is_keyword(s: &str) -> bool {
    KEYWORDS.contains(&s)
}

/// One extracted function: its body tokens (braces included) plus
/// enough naming context to build a crate-local call graph.
#[derive(Clone)]
pub struct FnDef {
    /// Path relative to `rust/src`, `/`-separated.
    pub file: String,
    /// Enclosing `impl` type, if any.
    pub owner: Option<String>,
    pub name: String,
    /// Token slice from the opening `{` through the matching `}`.
    pub body: Vec<Tok>,
    pub line: usize,
    pub is_test: bool,
}

impl FnDef {
    /// `Type::name` for methods, plain `name` for free functions.
    pub fn qname(&self) -> String {
        match &self.owner {
            Some(o) => format!("{o}::{}", self.name),
            None => self.name.clone(),
        }
    }

    /// Crate-unique key (two files may define a same-named method).
    pub fn key(&self) -> String {
        format!("{}:{}", self.file, self.qname())
    }
}

/// `toks[i]` is `{`; return the index just past the matching `}`.
pub fn match_brace(toks: &[Tok], mut i: usize) -> usize {
    let mut depth = 0i64;
    while i < toks.len() {
        match toks[i].text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                if depth == 0 {
                    return i + 1;
                }
            }
            _ => {}
        }
        i += 1;
    }
    toks.len()
}

/// A lexical region (mod or impl body): functions inside inherit the
/// owner type and test-ness.
struct Region {
    end: usize,
    owner: Option<String>,
    is_test: bool,
}

/// Extract every function (including nested and test ones) from a
/// file's token stream.
pub fn extract_functions(file: &str, toks: &[Tok]) -> Vec<FnDef> {
    let n = toks.len();
    let mut fns: Vec<FnDef> = Vec::new();
    let mut regions: Vec<Region> = Vec::new();
    let mut pending_cfg_test = false;
    let mut pending_test_attr = false;
    let mut i = 0usize;
    while i < n {
        let text = toks[i].text.as_str();
        let kind = toks[i].kind;
        regions.retain(|r| i < r.end);
        let owner = regions.iter().rev().find_map(|r| r.owner.clone());
        let in_test = regions.iter().any(|r| r.is_test);
        // attribute: #[...] — watch for cfg(test) and #[test]
        if text == "#" && i + 1 < n && toks[i + 1].text == "[" {
            let mut end = i + 2;
            let mut depth = 1i64;
            let mut attr: Vec<&str> = Vec::new();
            while end < n && depth > 0 {
                let t = toks[end].text.as_str();
                if t == "[" {
                    depth += 1;
                } else if t == "]" {
                    depth -= 1;
                }
                if depth > 0 {
                    attr.push(t);
                }
                end += 1;
            }
            if attr.contains(&"cfg") && attr.contains(&"test") {
                pending_cfg_test = true;
            }
            if attr.first() == Some(&"test") {
                pending_test_attr = true;
            }
            i = end;
            continue;
        }
        if text == "mod" && kind == TokKind::Ident {
            let mut j = i + 1;
            while j < n && toks[j].text != "{" && toks[j].text != ";" {
                j += 1;
            }
            if j < n && toks[j].text == "{" {
                let end = match_brace(toks, j);
                regions.push(Region { end, owner: None, is_test: pending_cfg_test });
            }
            pending_cfg_test = false;
            i = j + 1;
            continue;
        }
        if text == "impl" && kind == TokKind::Ident {
            let mut j = i + 1;
            // skip generic params <...>
            if j < n && toks[j].text == "<" {
                let mut d = 1i64;
                j += 1;
                while j < n && d > 0 {
                    if toks[j].text == "<" {
                        d += 1;
                    } else if toks[j].text == ">" {
                        d -= 1;
                    }
                    j += 1;
                }
            }
            let seg_start = j;
            while j < n && toks[j].text != "{" {
                j += 1;
            }
            let seg = &toks[seg_start..j.min(n)];
            let names: Vec<&str> = seg
                .iter()
                .filter(|t| t.kind == TokKind::Ident && !is_keyword(&t.text))
                .map(|t| t.text.as_str())
                .collect();
            // `impl Trait for Type` — the owner is the type after `for`
            let forpos = seg.iter().position(|t| t.text == "for");
            let tname: Option<String> = match forpos {
                Some(p) => seg[p + 1..]
                    .iter()
                    .find(|t| t.kind == TokKind::Ident && !is_keyword(&t.text))
                    .map(|t| t.text.clone()),
                None => names.first().map(|s| s.to_string()),
            };
            let end = match_brace(toks, j);
            regions.push(Region { end, owner: tname, is_test: pending_cfg_test });
            pending_cfg_test = false;
            i = j + 1;
            continue;
        }
        if text == "fn" && kind == TokKind::Ident && i + 1 < n && toks[i + 1].kind == TokKind::Ident
        {
            let name = toks[i + 1].text.clone();
            let fline = toks[i + 1].line;
            let mut j = i + 2;
            // scan for the body `{` at paren depth 0, or a trailing `;`
            let mut pd = 0i64;
            while j < n {
                match toks[j].text.as_str() {
                    "(" => pd += 1,
                    ")" => pd -= 1,
                    "{" if pd == 0 => break,
                    ";" if pd == 0 => break,
                    _ => {}
                }
                j += 1;
            }
            if j < n && toks[j].text == "{" {
                let end = match_brace(toks, j);
                fns.push(FnDef {
                    file: file.to_string(),
                    owner: owner.clone(),
                    name,
                    body: toks[j..end].to_vec(),
                    line: fline,
                    is_test: in_test || pending_test_attr || pending_cfg_test,
                });
            }
            pending_test_attr = false;
            pending_cfg_test = false;
            i = j + 1;
            continue;
        }
        if pending_cfg_test
            && matches!(text, "use" | "struct" | "enum" | "const" | "static" | "type")
        {
            pending_cfg_test = false;
        }
        i += 1;
    }
    fns
}

/// Ubiquitous std container/iterator/option method names: calls in
/// method position with these names never resolve to crate functions
/// (a crate fn that happens to share the name would create absurd
/// cross-type call-graph edges, e.g. `Vec::push` -> `TreeReducer::push`).
pub const STD_METHOD_SKIP: &[&str] = &[
    "push", "pop", "insert", "remove", "get", "get_mut", "len", "is_empty", "iter", "iter_mut",
    "into_iter", "next", "extend", "drain", "clear", "contains", "contains_key", "split_at",
    "split_at_mut", "map", "filter", "zip", "enumerate", "sum", "min", "max", "abs", "sqrt",
    "powi", "send", "recv", "join", "lock", "read", "write", "last", "first", "new",
];

const DEBUG_MACROS: &[&str] = &["debug_assert", "debug_assert_eq", "debug_assert_ne"];

/// Token ranges covered by `debug_assert*!(...)` invocations — these
/// compile out in release builds, so the hot-path rule ignores them.
pub fn debug_spans(body: &[Tok]) -> Vec<(usize, usize)> {
    let mut spans: Vec<(usize, usize)> = Vec::new();
    let mut k = 0usize;
    while k < body.len() {
        let is_dbg = body[k].kind == TokKind::Ident
            && DEBUG_MACROS.contains(&body[k].text.as_str())
            && k + 2 < body.len()
            && body[k + 1].text == "!"
            && matches!(body[k + 2].text.as_str(), "(" | "[" | "{");
        if is_dbg {
            let opener = body[k + 2].text.clone();
            let close = match opener.as_str() {
                "(" => ")",
                "[" => "]",
                _ => "}",
            };
            let mut depth = 1i64;
            let mut j = k + 3;
            while j < body.len() && depth > 0 {
                if body[j].text == opener {
                    depth += 1;
                } else if body[j].text == close {
                    depth -= 1;
                }
                j += 1;
            }
            spans.push((k, j));
            k = j;
            continue;
        }
        k += 1;
    }
    spans
}

pub fn in_spans(spans: &[(usize, usize)], k: usize) -> bool {
    spans.iter().any(|&(a, b)| a <= k && k < b)
}

/// One call site inside a function body.
pub struct Call {
    /// `Some("Type")` for `Type::name(...)`, `None` otherwise.
    pub owner: Option<String>,
    pub name: String,
    pub line: usize,
    pub is_macro: bool,
}

/// Extract call sites (fn calls, method calls, macro invocations) from
/// a body, skipping `debug_assert*!` contents and std method names.
pub fn calls_of(body: &[Tok]) -> Vec<Call> {
    let mut out: Vec<Call> = Vec::new();
    let spans = debug_spans(body);
    for k in 0..body.len() {
        if in_spans(&spans, k) {
            continue;
        }
        let t = &body[k];
        if t.kind != TokKind::Ident || is_keyword(&t.text) {
            continue;
        }
        let nxt = if k + 1 < body.len() { body[k + 1].text.as_str() } else { "" };
        let prev = if k > 0 { body[k - 1].text.as_str() } else { "" };
        if nxt == "!" {
            out.push(Call { owner: None, name: t.text.clone(), line: t.line, is_macro: true });
            continue;
        }
        if nxt == "(" {
            if prev == "." {
                if !STD_METHOD_SKIP.contains(&t.text.as_str()) {
                    out.push(Call {
                        owner: None,
                        name: t.text.clone(),
                        line: t.line,
                        is_macro: false,
                    });
                }
            } else if prev == "::" && k >= 2 && body[k - 2].kind == TokKind::Ident {
                out.push(Call {
                    owner: Some(body[k - 2].text.clone()),
                    name: t.text.clone(),
                    line: t.line,
                    is_macro: false,
                });
            } else {
                out.push(Call { owner: None, name: t.text.clone(), line: t.line, is_macro: false });
            }
        }
    }
    out
}
