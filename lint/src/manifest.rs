//! Parser for `lint/hotpath.toml` — a deliberately tiny TOML subset:
//! `#` comments, `[section]` headers, and `"key" = "value"` pairs.
//! Keys in `[roots]` register hot-path entry points; keys in `[allow]`
//! are call-graph allowlist entries with a justification as the value.

use std::collections::BTreeMap;

/// Parse the manifest text into `(roots, allow)`.
///
/// Returns `Err` with a line message on malformed non-comment lines so
/// a typo in the manifest fails the lint run instead of silently
/// dropping a root.
pub fn parse_manifest(src: &str) -> Result<(Vec<String>, BTreeMap<String, String>), String> {
    let mut roots: Vec<String> = Vec::new();
    let mut allow: BTreeMap<String, String> = BTreeMap::new();
    let mut section = String::new();
    for (idx, raw) in src.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(stripped) = line.strip_prefix('[') {
            section = stripped.trim_end_matches(']').to_string();
            continue;
        }
        let (k, v) = match line.split_once('=') {
            Some((k, v)) => (k.trim().trim_matches('"'), v.trim().trim_matches('"')),
            None => return Err(format!("hotpath.toml:{}: expected `key = value`", idx + 1)),
        };
        match section.as_str() {
            "roots" => roots.push(k.to_string()),
            "allow" => {
                allow.insert(k.to_string(), v.to_string());
            }
            other => {
                return Err(format!("hotpath.toml:{}: unknown section [{other}]", idx + 1));
            }
        }
    }
    Ok((roots, allow))
}
