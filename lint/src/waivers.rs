//! Waiver comments: `// lint:allow(<rule-id>): <justification>`.
//!
//! A waiver on line L covers violations on lines L and L+1, so both
//! trailing same-line comments and a comment on the line above work.
//! The justification is mandatory — a waiver without one is itself a
//! violation (rule `waiver`), keeping the escape hatch auditable.

use std::collections::BTreeMap;

/// Per-file waiver index: line -> waived rule id.
#[derive(Default)]
pub struct Waivers {
    map: BTreeMap<usize, String>,
}

impl Waivers {
    /// Does a waiver for `rule` cover `line`?
    pub fn covers(&self, rule: &str, line: usize) -> bool {
        if self.map.get(&line).map(String::as_str) == Some(rule) {
            return true;
        }
        line > 0 && self.map.get(&(line - 1)).map(String::as_str) == Some(rule)
    }
}

/// Scan a file's line comments for waivers. Returns the index plus the
/// `(line, rule)` list of waivers missing a justification.
pub fn parse(comments: &[(usize, String)]) -> (Waivers, Vec<(usize, String)>) {
    let mut w = Waivers::default();
    let mut bad: Vec<(usize, String)> = Vec::new();
    for (line, text) in comments {
        let Some(pos) = text.find("lint:allow(") else {
            continue;
        };
        let after = &text[pos + "lint:allow(".len()..];
        let Some(close) = after.find(')') else {
            continue;
        };
        let rule = after[..close].trim().to_string();
        if rule.is_empty() || !rule.chars().all(|c| c.is_ascii_lowercase() || c == '-') {
            continue;
        }
        let mut rest = after[close + 1..].trim();
        if let Some(r) = rest.strip_prefix(':') {
            rest = r.trim();
        }
        if rest.is_empty() {
            bad.push((*line, rule));
            continue;
        }
        w.map.insert(*line, rule);
    }
    (w, bad)
}
