//! Fixture corpus: every rule family has a pass fixture (clean code
//! the lint must accept) and a fail fixture (a violation it must
//! flag). Fixtures live under `lint/fixtures/` and are lexed by the
//! lint, never compiled.

use std::path::Path;

use cowclip_lint::{lint_sources, Config, Violation};

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(name);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("{}: {e}", p.display()))
}

/// Lint one fixture under the relative path `rel` (rules key off path
/// patterns, so the test picks the path that activates the rule).
fn run_one(rel: &str, name: &str, cfg: &Config) -> Vec<Violation> {
    lint_sources(&[(rel.to_string(), fixture(name))], cfg)
}

fn rules(vs: &[Violation]) -> Vec<&'static str> {
    vs.iter().map(|v| v.rule).collect()
}

fn hotpath_cfg(roots: &[&str]) -> Config {
    let mut cfg = Config::repo_policy();
    cfg.roots = roots.iter().map(|s| s.to_string()).collect();
    cfg.allow
        .insert("allowed_helper".to_string(), "allowlisted by the fixture config".to_string());
    cfg
}

#[test]
fn hotpath_pass() {
    let cfg = hotpath_cfg(&["hot/case.rs:hot_root", "hot/case.rs:hot_with_waiver"]);
    let vs = run_one("hot/case.rs", "pass/hotpath_alloc.rs", &cfg);
    assert!(vs.is_empty(), "expected clean, got: {vs:?}");
}

#[test]
fn hotpath_fail_flags_transitive_alloc() {
    // Only list roots that exist in this fixture: a missing root is
    // itself a hotpath-alloc violation and would mask the assertion.
    let cfg = hotpath_cfg(&["hot/case.rs:hot_root"]);
    let vs = run_one("hot/case.rs", "fail/hotpath_alloc.rs", &cfg);
    assert!(!vs.is_empty(), "transitive vec![] must be flagged");
    assert!(vs.iter().all(|v| v.rule == "hotpath-alloc"), "{vs:?}");
    assert!(
        vs.iter().any(|v| v.msg.contains("vec!") && v.msg.contains("hot via")),
        "wanted the hot via chain in {vs:?}"
    );
}

#[test]
fn determinism_pass() {
    let vs = run_one("coordinator/fixture.rs", "pass/determinism.rs", &Config::repo_policy());
    assert!(vs.is_empty(), "expected clean, got: {vs:?}");
}

#[test]
fn determinism_fail_flags_unordered() {
    let vs = run_one("coordinator/fixture.rs", "fail/determinism.rs", &Config::repo_policy());
    assert!(!vs.is_empty());
    assert!(vs.iter().all(|v| v.rule == "determinism"), "{vs:?}");
    assert!(vs.iter().any(|v| v.msg.contains("HashMap")), "{vs:?}");
    assert!(vs.iter().any(|v| v.msg.contains("float sum")), "{vs:?}");
}

#[test]
fn panic_pass() {
    let vs = run_one("serve/queue.rs", "pass/panic.rs", &Config::repo_policy());
    assert!(vs.is_empty(), "expected clean, got: {vs:?}");
}

#[test]
fn panic_fail_flags_unwrap_and_indexing() {
    let vs = run_one("serve/queue.rs", "fail/panic.rs", &Config::repo_policy());
    assert_eq!(rules(&vs), vec!["panic", "panic"], "{vs:?}");
    assert!(vs.iter().any(|v| v.msg.contains(".unwrap()")), "{vs:?}");
    assert!(vs.iter().any(|v| v.msg.contains("slice index")), "{vs:?}");
}

#[test]
fn lock_order_pass() {
    let vs = run_one("model/store.rs", "pass/lock_order.rs", &Config::repo_policy());
    assert!(vs.is_empty(), "expected clean, got: {vs:?}");
}

#[test]
fn lock_order_fail_flags_cycle() {
    let vs = run_one("model/store.rs", "fail/lock_order.rs", &Config::repo_policy());
    assert!(!vs.is_empty(), "opposite acquisition orders must be flagged");
    assert!(vs.iter().all(|v| v.rule == "lock-order"), "{vs:?}");
    assert!(vs[0].msg.contains("cycle"), "{vs:?}");
}

#[test]
fn unsafe_confinement_pass_inside_simd_tree() {
    let vs = run_one(
        "reference/simd/x86.rs",
        "pass/unsafe_confinement.rs",
        &Config::repo_policy(),
    );
    assert!(vs.is_empty(), "expected clean, got: {vs:?}");
}

#[test]
fn unsafe_confinement_fail_outside_simd_tree() {
    let vs = run_one("serve/helper.rs", "fail/unsafe_confinement.rs", &Config::repo_policy());
    assert_eq!(rules(&vs), vec!["unsafe-confinement", "unsafe-confinement"], "{vs:?}");
    assert!(vs.iter().all(|v| v.msg.contains("reference/simd/")), "{vs:?}");
    // comments and string literals mentioning unsafe are not tokens:
    // exactly the two real occurrences are flagged, nothing from line 1-5
    assert!(vs.iter().all(|v| v.line > 5), "{vs:?}");
}

#[test]
fn obs_inert_pass() {
    let cfg = hotpath_cfg(&["hot/case.rs:hot_root"]);
    let vs = run_one("hot/case.rs", "pass/obs_inert.rs", &cfg);
    assert!(vs.is_empty(), "expected clean, got: {vs:?}");
}

#[test]
fn obs_inert_fail_flags_registration_and_snapshot() {
    let cfg = hotpath_cfg(&["hot/case.rs:hot_root"]);
    let vs = run_one("hot/case.rs", "fail/obs_inert.rs", &cfg);
    assert!(!vs.is_empty(), "obs registration in the hot graph must be flagged");
    assert!(vs.iter().all(|v| v.rule == "obs-inert"), "{vs:?}");
    assert!(
        vs.iter().any(|v| v.msg.contains("obs::counter") && v.msg.contains("hot via")),
        "wanted the transitive counter registration with its chain in {vs:?}"
    );
    assert!(vs.iter().any(|v| v.msg.contains("obs::snapshot_metrics")), "{vs:?}");
}

#[test]
fn waiver_without_justification_is_flagged() {
    let vs =
        run_one("hot/case.rs", "fail/waiver_missing_justification.rs", &Config::repo_policy());
    assert_eq!(rules(&vs), vec!["waiver"], "{vs:?}");
    assert!(vs[0].msg.contains("without a justification"), "{vs:?}");
}

#[test]
fn manifest_parses_roots_and_allow() {
    let src = "# comment\n[roots]\n\"a.rs:f\" = \"why\"\n[allow]\n\"g\" = \"because\"\n";
    let (roots, allow) = cowclip_lint::manifest::parse_manifest(src).expect("parses");
    assert_eq!(roots, vec!["a.rs:f".to_string()]);
    assert_eq!(allow.get("g").map(String::as_str), Some("because"));
    assert!(cowclip_lint::manifest::parse_manifest("[roots]\nnot a pair\n").is_err());
}
