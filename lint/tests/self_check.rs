//! Self-check: the real `rust/src` tree must be violation-free under
//! the shipped policy + manifest. This is the same run CI performs via
//! `cargo run -p cowclip-lint`, expressed as a test so `cargo test -p
//! cowclip-lint` also covers it.

use std::path::Path;

use cowclip_lint::Config;

#[test]
fn real_tree_is_violation_free() {
    let manifest_dir = Path::new(env!("CARGO_MANIFEST_DIR"));
    let repo_root = manifest_dir.parent().expect("lint crate lives inside the repo");
    let mut cfg = Config::repo_policy();
    cfg.load_manifest(&manifest_dir.join("hotpath.toml")).expect("hotpath.toml parses");
    assert!(!cfg.roots.is_empty(), "hotpath.toml must register hot-path roots");
    let vs = cowclip_lint::lint_dir(&repo_root.join("rust").join("src"), &cfg)
        .expect("rust/src is readable");
    let rendered: Vec<String> = vs.iter().map(|v| v.to_string()).collect();
    assert!(vs.is_empty(), "rust/src has lint violations:\n{}", rendered.join("\n"));
}
