"""Clipping-variant semantics (Table 7 ablation grid)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import clipping
from compile.clipping import (
    H_CLIP_R, H_CLIP_T, H_CLIP_ZETA, N_HYPERS, get_clip,
)
from compile.schemas import CRITEO_SYNTH, Schema

TINY = Schema(name="tiny", n_dense=2, vocab_sizes=(4, 3, 2))


def hyp(r=1.0, zeta=1e-4, clip_t=1.0):
    h = np.zeros(N_HYPERS, np.float32)
    h[H_CLIP_R], h[H_CLIP_ZETA], h[H_CLIP_T] = r, zeta, clip_t
    return jnp.asarray(h)


def setup(seed=0, scale=5.0):
    v, d = TINY.total_vocab, 4
    k = jax.random.split(jax.random.PRNGKey(seed), 3)
    g = jax.random.normal(k[0], (v, d)) * scale
    w = jax.random.normal(k[1], (v, d)) * 0.1
    counts = jnp.floor(jax.random.uniform(k[2], (v,)) * 3)
    return g, w, counts


def test_none_is_identity():
    g, w, c = setup()
    np.testing.assert_array_equal(get_clip("none")(g, w, c, hyp(), TINY), g)


def test_global_clips_total_norm():
    g, w, c = setup()
    out = get_clip("global")(g, w, c, hyp(clip_t=1.0), TINY)
    assert float(jnp.linalg.norm(out)) <= 1.0 + 1e-5
    # direction preserved
    np.testing.assert_allclose(
        out / jnp.linalg.norm(out), g / jnp.linalg.norm(g), rtol=1e-5
    )


def test_global_noop_below_threshold():
    g, w, c = setup(scale=1e-4)
    out = get_clip("global")(g, w, c, hyp(clip_t=100.0), TINY)
    np.testing.assert_allclose(out, g, rtol=1e-6)


def test_field_clips_each_field_independently():
    g, w, c = setup()
    out = get_clip("field")(g, w, c, hyp(clip_t=0.5), TINY)
    for lo, vs in zip(TINY.offsets, TINY.vocab_sizes):
        assert float(jnp.linalg.norm(out[lo : lo + vs])) <= 0.5 + 1e-5


def test_column_clips_each_row():
    g, w, c = setup()
    out = get_clip("column")(g, w, c, hyp(clip_t=0.25), TINY)
    norms = jnp.linalg.norm(out, axis=-1)
    assert bool(jnp.all(norms <= 0.25 + 1e-5))


def test_adafield_threshold_uses_field_count_and_weight_norm():
    g, w, c = setup()
    out = get_clip("adafield")(g, w, c, hyp(r=1.0, zeta=1e-6), TINY)
    for lo, vs in zip(TINY.offsets, TINY.vocab_sizes):
        gf, wf = g[lo : lo + vs], w[lo : lo + vs]
        cnt_f = float(jnp.sum(c[lo : lo + vs]))
        thresh = cnt_f * max(float(jnp.linalg.norm(wf)), 1e-6)
        assert float(jnp.linalg.norm(out[lo : lo + vs])) <= thresh + 1e-4


def test_cowclip_row_norm_bound():
    g, w, c = setup()
    out = get_clip("cowclip")(g, w, c, hyp(r=1.0, zeta=1e-5), TINY, use_pallas=False)
    wnorm = jnp.linalg.norm(w, axis=-1)
    bound = c * jnp.maximum(wnorm, 1e-5)
    norms = jnp.linalg.norm(out, axis=-1)
    assert bool(jnp.all(norms <= bound + 1e-4))


@pytest.mark.parametrize("mode", sorted(clipping.CLIP_MODES))
def test_all_modes_preserve_shape_and_finiteness(mode):
    g, w, c = setup()
    kwargs = {"use_pallas": False} if mode == "cowclip" else {}
    out = clipping.CLIP_MODES[mode](g, w, c, hyp(), TINY, **kwargs)
    assert out.shape == g.shape
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.parametrize("mode", ["global", "field", "column", "adafield", "cowclip"])
def test_clipping_never_increases_row_norm(mode):
    g, w, c = setup()
    kwargs = {"use_pallas": False} if mode == "cowclip" else {}
    out = clipping.CLIP_MODES[mode](g, w, c, hyp(), TINY, **kwargs)
    assert bool(
        jnp.all(jnp.linalg.norm(out, axis=-1) <= jnp.linalg.norm(g, axis=-1) + 1e-5)
    )


def test_field_slices_cover_criteo():
    slices = clipping._field_slices(CRITEO_SYNTH)
    assert slices[0][0] == 0
    assert slices[-1][1] == CRITEO_SYNTH.total_vocab
    for (a, b), (c2, _) in zip(slices, slices[1:]):
        assert b == c2
