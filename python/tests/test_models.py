"""L2 model correctness: shapes, spec consistency, architectural semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.models import MODELS, ModelCfg, get_model
from compile.models import common
from compile.schemas import AVAZU_SYNTH, CRITEO_SYNTH

CFG = ModelCfg(use_pallas=False)  # oracles: faster to trace in tests


def init_params(model_name, schema, cfg, seed=0, embed_scale=0.01):
    model = get_model(model_name)
    params = []
    key = jax.random.PRNGKey(seed)
    for e in model.spec(schema, cfg):
        key, sub = jax.random.split(key)
        scale = embed_scale if e.group in ("embed", "wide") else 0.1
        params.append(jax.random.normal(sub, e.shape) * scale)
    return params


def make_batch(schema, b, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    cols = []
    for off, vs in zip(schema.offsets, schema.vocab_sizes):
        key, sub = jax.random.split(key)
        cols.append(jax.random.randint(sub, (b,), off, off + vs))
    x_cat = jnp.stack(cols, axis=1).astype(jnp.int32)
    x_dense = jax.random.normal(ks[1], (b, schema.n_dense))
    y = (jax.random.uniform(ks[2], (b,)) < 0.3).astype(jnp.float32)
    return x_cat, x_dense, y


@pytest.mark.parametrize("model_name", sorted(MODELS))
@pytest.mark.parametrize("schema", [CRITEO_SYNTH, AVAZU_SYNTH], ids=lambda s: s.name)
def test_fwd_shape_and_finite(model_name, schema):
    params = init_params(model_name, schema, CFG)
    x_cat, x_dense, _ = make_batch(schema, 17)
    logits = get_model(model_name).fwd(params, x_cat, x_dense, schema, CFG)
    assert logits.shape == (17,)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("model_name", sorted(MODELS))
def test_spec_groups_and_embedding_dominance(model_name):
    spec = get_model(model_name).spec(CRITEO_SYNTH, CFG)
    names = [e.name for e in spec]
    assert len(names) == len(set(names)), "duplicate param names"
    groups = {e.group for e in spec}
    assert groups <= {"embed", "wide", "dense"}
    assert spec[0].group == "embed"
    n_embed = sum(np.prod(e.shape) for e in spec if e.group in ("embed", "wide"))
    n_total = sum(np.prod(e.shape) for e in spec)
    # The paper's Table 1 point: embeddings dominate the parameter count.
    assert n_embed / n_total > 0.5


def test_wd_is_linear_in_wide_table():
    """W&D wide stream is exactly LR: doubling wide weights doubles the
    first-order contribution."""
    schema = CRITEO_SYNTH
    params = init_params("wd", schema, CFG)
    x_cat, x_dense, _ = make_batch(schema, 8)
    wd = get_model("wd")
    base = wd.fwd(params, x_cat, x_dense, schema, CFG)
    p2 = list(params)
    p2[1] = params[1] * 2.0  # wide_table
    doubled = wd.fwd(p2, x_cat, x_dense, schema, CFG)
    zeroed = list(params)
    zeroed[1] = jnp.zeros_like(params[1])
    no_wide = wd.fwd(zeroed, x_cat, x_dense, schema, CFG)
    # doubling the wide table adds exactly one more copy of its logit
    np.testing.assert_allclose(doubled - base, base - no_wide, rtol=1e-3, atol=1e-5)


def test_deepfm_equals_wd_plus_fm():
    """DeepFM = W&D + FM second-order term (shared spec layout)."""
    from compile.kernels import fm2_ref

    schema = CRITEO_SYNTH
    params = init_params("deepfm", schema, CFG)
    x_cat, x_dense, _ = make_batch(schema, 11)
    d = get_model("deepfm").fwd(params, x_cat, x_dense, schema, CFG)
    w = get_model("wd").fwd(params, x_cat, x_dense, schema, CFG)
    embeds = params[0][x_cat]
    np.testing.assert_allclose(d - w, fm2_ref(embeds), rtol=1e-4, atol=1e-5)


def test_dcn_cross_zero_weights_is_identity():
    """With w_l = b_l = 0 the DCN cross stream is the identity on x0."""
    schema = CRITEO_SYNTH
    cfg = CFG
    model = get_model("dcn")
    params = init_params("dcn", schema, cfg)
    spec = model.spec(schema, cfg)
    params = [
        jnp.zeros_like(p) if e.name.startswith("cross_") else p
        for e, p in zip(spec, params)
    ]
    x_cat, x_dense, _ = make_batch(schema, 5)
    # head sees concat(x0, deep); verify via manual recomputation
    embeds = params[0][x_cat]
    x0 = common.deep_input(embeds, x_dense, schema)
    r = common.ParamReader([p for e, p in zip(spec, params) if e.name.startswith("mlp_")])
    deep = common.mlp_hidden_forward(r, x0, len(cfg.hidden))
    head_w = params[-2]
    head_b = params[-1]
    want = (jnp.concatenate([x0, deep], axis=-1) @ head_w + head_b)[:, 0]
    got = model.fwd(params, x_cat, x_dense, schema, cfg)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_dcnv2_cross_layer_formula():
    """One DCNv2 cross layer: x1 = x0 ⊙ (W x0 + b) + x0."""
    schema = AVAZU_SYNTH
    cfg = ModelCfg(use_pallas=False, n_cross=1, hidden=(8,))
    model = get_model("dcnv2")
    params = init_params("dcnv2", schema, cfg)
    spec = model.spec(schema, cfg)
    x_cat, x_dense, _ = make_batch(schema, 3)
    embeds = params[0][x_cat]
    x0 = common.deep_input(embeds, x_dense, schema)
    by_name = {e.name: p for e, p in zip(spec, params)}
    x1 = x0 * (x0 @ by_name["cross_W0"] + by_name["cross_b0"]) + x0
    h = jnp.maximum(x0 @ by_name["mlp_w0"] + by_name["mlp_b0"], 0.0)
    want = (jnp.concatenate([x1, h], axis=-1) @ by_name["head_w"] + by_name["head_b"])[:, 0]
    got = model.fwd(params, x_cat, x_dense, schema, cfg)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_pallas_and_oracle_models_agree():
    schema = CRITEO_SYNTH
    cfg_p = ModelCfg(use_pallas=True)
    cfg_r = ModelCfg(use_pallas=False)
    params = init_params("deepfm", schema, cfg_p)
    x_cat, x_dense, _ = make_batch(schema, 64)
    a = get_model("deepfm").fwd(params, x_cat, x_dense, schema, cfg_p)
    b = get_model("deepfm").fwd(params, x_cat, x_dense, schema, cfg_r)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_schema_offsets_partition_vocab():
    for schema in (CRITEO_SYNTH, AVAZU_SYNTH):
        offs = schema.offsets
        assert offs[0] == 0
        for i in range(1, len(offs)):
            assert offs[i] == offs[i - 1] + schema.vocab_sizes[i - 1]
        assert offs[-1] + schema.vocab_sizes[-1] == schema.total_vocab
