"""Adam reference semantics (mirrored bit-for-bit by rust/src/optim)."""

import jax.numpy as jnp
import numpy as np

from compile.optim import BETA1, BETA2, EPS, adam_update


def test_first_step_moves_by_lr_signwise():
    """At t=1 with zero state, |update| ≈ lr * g/(|g| + eps') → ~lr."""
    w = jnp.zeros((4,))
    g = jnp.array([1.0, -2.0, 0.5, -0.1])
    w2, m2, v2 = adam_update(w, jnp.zeros_like(w), jnp.zeros_like(w), g, 0.01, 1.0)
    np.testing.assert_allclose(np.abs(w2), 0.01, rtol=1e-4)
    np.testing.assert_allclose(np.sign(w2), -np.sign(g))
    np.testing.assert_allclose(m2, (1 - BETA1) * g, rtol=1e-6)
    np.testing.assert_allclose(v2, (1 - BETA2) * g * g, rtol=1e-6)


def test_zero_gradient_keeps_weights():
    w = jnp.array([1.0, -1.0])
    w2, m2, v2 = adam_update(w, jnp.zeros_like(w), jnp.zeros_like(w),
                             jnp.zeros_like(w), 0.1, 1.0)
    np.testing.assert_array_equal(w2, w)
    np.testing.assert_array_equal(m2, 0.0)
    np.testing.assert_array_equal(v2, 0.0)


def test_matches_manual_recurrence_over_steps():
    rng = np.random.RandomState(0)
    w = jnp.asarray(rng.randn(8).astype(np.float32))
    m = jnp.zeros_like(w)
    v = jnp.zeros_like(w)
    wm, mm, vm = np.asarray(w).copy(), np.zeros(8, np.float32), np.zeros(8, np.float32)
    lr = 3e-3
    for t in range(1, 20):
        g = rng.randn(8).astype(np.float32)
        w, m, v = adam_update(w, m, v, jnp.asarray(g), lr, float(t))
        mm = BETA1 * mm + (1 - BETA1) * g
        vm = BETA2 * vm + (1 - BETA2) * g * g
        mh = mm / (1 - BETA1**t)
        vh = vm / (1 - BETA2**t)
        wm = wm - lr * mh / (np.sqrt(vh) + EPS)
    np.testing.assert_allclose(np.asarray(w), wm, rtol=1e-5, atol=1e-7)


def test_bias_correction_shrinks_with_t():
    """Same gradient at large t (warm state) produces a smaller step than
    the bias-amplified first step would suggest."""
    g = jnp.array([1.0])
    w0 = jnp.array([0.0])
    _, m1, v1 = adam_update(w0, jnp.zeros(1), jnp.zeros(1), g, 0.01, 1.0)
    w_t1, _, _ = adam_update(w0, jnp.zeros(1), jnp.zeros(1), g, 0.01, 1.0)
    w_t100, _, _ = adam_update(w0, jnp.zeros(1), jnp.zeros(1), g, 0.01, 100.0)
    # with cold state but t=100, bias correction divides by ~1 -> tiny step
    assert abs(float(w_t100[0])) < abs(float(w_t1[0]))
