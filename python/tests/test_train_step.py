"""End-to-end semantics of the grad / apply / fwd program builders."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import optim
from compile.clipping import (
    H_CLIP_R, H_CLIP_ZETA, H_L2_EMBED, H_LR_DENSE, H_LR_EMBED, H_STEP, N_HYPERS,
)
from compile.kernels import cowclip_clip_ref
from compile.models import ModelCfg, get_model
from compile.schemas import Schema
from compile.train_step import bce_with_logits, build_apply_fn, build_fwd_fn, build_grad_fn

TINY = Schema(name="tiny", n_dense=3, vocab_sizes=(5, 4, 2))
CFG = ModelCfg(use_pallas=False, hidden=(8, 8), n_cross=2, embed_dim=4)


def init_params(model_name, schema=TINY, cfg=CFG, seed=0):
    model = get_model(model_name)
    params = []
    key = jax.random.PRNGKey(seed)
    for e in model.spec(schema, cfg):
        key, sub = jax.random.split(key)
        scale = 0.01 if e.group in ("embed", "wide") else 0.2
        params.append(jax.random.normal(sub, e.shape) * scale)
    return params


def make_batch(schema, b, seed=1):
    key = jax.random.PRNGKey(seed)
    cols = []
    for off, vs in zip(schema.offsets, schema.vocab_sizes):
        key, sub = jax.random.split(key)
        cols.append(jax.random.randint(sub, (b,), off, off + vs))
    x_cat = jnp.stack(cols, axis=1).astype(jnp.int32)
    key, k1, k2 = jax.random.split(key, 3)
    x_dense = jax.random.normal(k1, (b, schema.n_dense))
    y = (jax.random.uniform(k2, (b,)) < 0.4).astype(jnp.float32)
    return x_cat, x_dense, y


def hypers(lr_dense=1e-3, lr_embed=1e-3, l2=0.0, r=1.0, zeta=1e-5, clip_t=1e9, step=1.0):
    h = np.zeros(N_HYPERS, np.float32)
    h[H_LR_DENSE], h[H_LR_EMBED], h[H_L2_EMBED] = lr_dense, lr_embed, l2
    h[H_CLIP_R], h[H_CLIP_ZETA], h[5], h[H_STEP] = r, zeta, clip_t, step
    return jnp.asarray(h)


def test_bce_matches_manual():
    logits = jnp.array([0.0, 2.0, -3.0])
    y = jnp.array([1.0, 0.0, 1.0])
    p = jax.nn.sigmoid(logits)
    want = -jnp.mean(y * jnp.log(p) + (1 - y) * jnp.log(1 - p))
    np.testing.assert_allclose(bce_with_logits(logits, y), want, rtol=1e-5)


def test_counts_are_exact_occurrences():
    fn, _ = build_grad_fn("wd", TINY, CFG)
    params = init_params("wd")
    x_cat, x_dense, y = make_batch(TINY, 32)
    out = fn(*params, x_cat, x_dense, y)
    counts = out[-2]
    want = np.zeros(TINY.total_vocab)
    for gid in np.asarray(x_cat).flatten():
        want[gid] += 1
    np.testing.assert_array_equal(np.asarray(counts), want)
    assert counts.sum() == 32 * TINY.n_cat


def test_grad_zero_for_absent_ids():
    fn, _ = build_grad_fn("deepfm", TINY, CFG)
    params = init_params("deepfm")
    x_cat, x_dense, y = make_batch(TINY, 4)
    out = fn(*params, x_cat, x_dense, y)
    g_embed, counts = out[0], out[-2]
    absent = np.asarray(counts) == 0
    assert absent.any(), "test batch should miss some ids"
    np.testing.assert_array_equal(np.asarray(g_embed)[absent], 0.0)


def test_grad_matches_jax_grad_directly():
    model = get_model("dcn")
    fn, _ = build_grad_fn("dcn", TINY, CFG)
    params = init_params("dcn")
    x_cat, x_dense, y = make_batch(TINY, 16)
    out = fn(*params, x_cat, x_dense, y)
    n = len(params)
    grads, loss = out[:n], out[-1]

    def loss_fn(ps):
        return bce_with_logits(model.fwd(ps, x_cat, x_dense, TINY, CFG), y)

    want_loss, want_grads = jax.value_and_grad(loss_fn)(params)
    np.testing.assert_allclose(loss, want_loss, rtol=1e-6)
    for g, wg in zip(grads, want_grads):
        np.testing.assert_allclose(g, wg, rtol=1e-5, atol=1e-7)


def test_microbatch_accumulation_equals_big_batch():
    """mean-of-means over equal microbatches == big-batch gradient; counts
    add. This is the invariant the Rust coordinator's accumulator relies
    on (DESIGN.md §2)."""
    fn, _ = build_grad_fn("deepfm", TINY, CFG)
    params = init_params("deepfm")
    x_cat, x_dense, y = make_batch(TINY, 64)
    big = fn(*params, x_cat, x_dense, y)
    n = len(params)

    acc = [jnp.zeros_like(g) for g in big[:n]]
    acc_counts = jnp.zeros_like(big[-2])
    for i in range(4):
        sl = slice(16 * i, 16 * (i + 1))
        out = fn(*params, x_cat[sl], x_dense[sl], y[sl])
        acc = [a + g / 4.0 for a, g in zip(acc, out[:n])]
        acc_counts = acc_counts + out[-2]
    for a, g in zip(acc, big[:n]):
        np.testing.assert_allclose(a, g, rtol=1e-4, atol=1e-7)
    np.testing.assert_array_equal(acc_counts, big[-2])


def test_apply_none_is_plain_adam_with_l2():
    model = get_model("wd")
    spec = model.spec(TINY, CFG)
    n = len(spec)
    params = init_params("wd")
    ms = [jnp.zeros_like(p) for p in params]
    vs = [jnp.zeros_like(p) for p in params]
    grads = [jnp.ones_like(p) * 0.1 for p in params]
    counts = jnp.ones((TINY.total_vocab,))
    h = hypers(lr_dense=1e-2, lr_embed=1e-3, l2=0.5, step=3.0)

    fn = build_apply_fn("wd", TINY, CFG, "none")
    out = fn(*params, *ms, *vs, *grads, counts, h)
    for i, e in enumerate(spec):
        g = grads[i]
        if e.group in ("embed", "wide"):
            g = g + 0.5 * params[i]
            lr = 1e-3
        else:
            lr = 1e-2
        w2, m2, v2 = optim.adam_update(params[i], ms[i], vs[i], g, lr, 3.0)
        np.testing.assert_allclose(out[i], w2, rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(out[n + i], m2, rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(out[2 * n + i], v2, rtol=1e-5, atol=1e-7)


def test_apply_cowclip_composes_clip_l2_adam():
    model = get_model("deepfm")
    spec = model.spec(TINY, CFG)
    params = init_params("deepfm")
    ms = [jnp.ones_like(p) * 0.01 for p in params]
    vs = [jnp.ones_like(p) * 0.02 for p in params]
    key = jax.random.PRNGKey(3)
    grads = []
    for p in params:
        key, sub = jax.random.split(key)
        grads.append(jax.random.normal(sub, p.shape) * 2.0)
    counts = jnp.floor(
        jax.random.uniform(jax.random.PRNGKey(4), (TINY.total_vocab,)) * 3
    )
    h = hypers(lr_dense=1e-3, lr_embed=1e-4, l2=0.1, r=1.0, zeta=1e-5, step=7.0)

    fn = build_apply_fn("deepfm", TINY, CFG, "cowclip")
    out = fn(*params, *ms, *vs, *grads, counts, h)
    # manual: embed table is params[0]
    g0 = cowclip_clip_ref(grads[0], params[0], counts, jnp.float32(1.0), jnp.float32(1e-5))
    g0 = g0 + 0.1 * params[0]
    w2, _, _ = optim.adam_update(params[0], ms[0], vs[0], g0, 1e-4, 7.0)
    np.testing.assert_allclose(out[0], w2, rtol=1e-5, atol=1e-7)
    # wide table: L2 but NO clipping
    g1 = grads[1] + 0.1 * params[1]
    w2, _, _ = optim.adam_update(params[1], ms[1], vs[1], g1, 1e-4, 7.0)
    np.testing.assert_allclose(out[1], w2, rtol=1e-5, atol=1e-7)


def test_fwd_matches_model_fwd():
    fn, _ = build_fwd_fn("dcnv2", TINY, CFG)
    params = init_params("dcnv2")
    x_cat, x_dense, _ = make_batch(TINY, 9)
    (logits,) = fn(*params, x_cat, x_dense)
    want = get_model("dcnv2").fwd(params, x_cat, x_dense, TINY, CFG)
    np.testing.assert_allclose(logits, want, rtol=1e-6)


def test_no_dense_schema_drops_x_dense_input():
    nd = Schema(name="nodense", n_dense=0, vocab_sizes=(4, 3))
    fn, inputs = build_grad_fn("wd", nd, CFG)
    assert inputs == ["x_cat", "y"]
    model = get_model("wd")
    params = []
    key = jax.random.PRNGKey(0)
    for e in model.spec(nd, CFG):
        key, sub = jax.random.split(key)
        params.append(jax.random.normal(sub, e.shape) * 0.05)
    x_cat = jnp.array([[0, 4], [1, 5]], jnp.int32)
    y = jnp.array([1.0, 0.0])
    out = fn(*params, x_cat, y)
    assert bool(jnp.isfinite(out[-1]))


@pytest.mark.parametrize("model_name", ["deepfm", "wd", "dcn", "dcnv2"])
def test_training_reduces_loss(model_name):
    """A few Adam steps on a fixed batch must reduce the loss — the
    minimal 'this trains' signal for every model."""
    gfn, _ = build_grad_fn(model_name, TINY, CFG)
    afn = build_apply_fn(model_name, TINY, CFG, "cowclip")
    params = init_params(model_name)
    n = len(params)
    ms = [jnp.zeros_like(p) for p in params]
    vs = [jnp.zeros_like(p) for p in params]
    x_cat, x_dense, y = make_batch(TINY, 64)

    losses = []
    for step in range(1, 16):
        out = gfn(*params, x_cat, x_dense, y)
        grads, counts, loss = out[:n], out[-2], out[-1]
        losses.append(float(loss))
        h = hypers(lr_dense=1e-2, lr_embed=1e-2, l2=1e-5, step=float(step))
        out = afn(*params, *ms, *vs, *grads, counts, h)
        params, ms, vs = list(out[:n]), list(out[n : 2 * n]), list(out[2 * n :])
    # CowClip intentionally throttles early updates (threshold ∝ ||w||,
    # tiny at init), so assert steady descent rather than a big drop.
    assert losses[-1] < losses[0] * 0.97, losses
    assert all(b <= a + 1e-4 for a, b in zip(losses, losses[1:])), losses
