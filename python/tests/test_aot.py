"""AOT manifest + lowering: the compile-path/Rust contract."""

import json
import os
import tempfile

import pytest

from compile import manifest as mf
from compile.aot import lower_artifact, main as aot_main, source_fingerprint
from compile.models import ModelCfg, get_model
from compile.schemas import SCHEMAS

CFG = ModelCfg(use_pallas=False)


def test_default_specs_cover_the_experiment_grid():
    specs = mf.default_artifact_specs()
    ids = {s.artifact_id for s in specs}
    assert len(ids) == len(specs), "duplicate artifact ids"
    # every model on every schema has grad@{64,512}, fwd, apply none+cowclip
    for schema in ("criteo_synth", "avazu_synth"):
        for model in mf.ALL_MODELS:
            assert f"{schema}-{model}-grad-b64" in ids
            assert f"{schema}-{model}-grad-b512" in ids
            assert f"{schema}-{model}-fwd-b{mf.EVAL_BATCH}" in ids
            assert f"{schema}-{model}-apply-none" in ids
            assert f"{schema}-{model}-apply-cowclip" in ids
    # Table 7 ablation artifacts
    for clip in mf.ABLATION_CLIPS:
        assert f"criteo_synth-deepfm-apply-{clip}" in ids


@pytest.mark.parametrize("kind", ["grad", "fwd", "apply"])
def test_input_layout_arity(kind):
    schema = SCHEMAS["criteo_synth"]
    n = len(get_model("deepfm").spec(schema, CFG))
    spec = mf.ArtifactSpec(kind, "deepfm", "criteo_synth", batch=64, clip="none")
    ins = mf.input_layout(spec, schema, CFG)
    if kind == "grad":
        assert len(ins) == n + 3  # x_cat, x_dense, y
        assert ins[-1]["name"] == "y"
    elif kind == "fwd":
        assert len(ins) == n + 2
    else:
        assert len(ins) == 4 * n + 2
        assert ins[-1] == {"name": "hypers", "dtype": "f32", "shape": [8]}
    assert mf.output_arity(spec, schema, CFG) == {
        "grad": n + 2, "fwd": 1, "apply": 3 * n
    }[kind]


def test_avazu_layout_has_no_dense_input():
    schema = SCHEMAS["avazu_synth"]
    spec = mf.ArtifactSpec("grad", "wd", "avazu_synth", batch=64)
    names = [i["name"] for i in mf.input_layout(spec, schema, CFG)]
    assert "x_dense" not in names
    assert names[-2:] == ["x_cat", "y"]


def test_manifest_json_roundtrip():
    m = mf.build_manifest(mf.default_artifact_specs(), CFG)
    s = json.dumps(m)
    m2 = json.loads(s)
    assert m2["version"] == mf.MANIFEST_VERSION
    assert set(m2["schemas"]) == {"criteo_synth", "avazu_synth"}
    assert len(m2["param_specs"]) == 8
    for art in m2["artifacts"]:
        assert art["kind"] in ("grad", "apply", "fwd")
        assert art["n_outputs"] > 0


def test_lower_small_artifact_produces_hlo_text():
    spec = mf.ArtifactSpec("fwd", "wd", "avazu_synth", batch=4)
    text = lower_artifact(spec, CFG)
    assert text.startswith("HloModule")
    assert "ROOT" in text


def test_fingerprint_changes_with_source(tmp_path):
    fp1 = source_fingerprint()
    assert fp1 == source_fingerprint(), "fingerprint must be deterministic"
    assert len(fp1) == 64


def test_aot_cli_only_filter(tmp_path):
    rc = aot_main([
        "--out-dir", str(tmp_path), "--only", "avazu_synth-wd-fwd", "--no-pallas",
    ])
    assert rc == 0
    files = os.listdir(tmp_path)
    assert any(f.endswith(".hlo.txt") for f in files)
    assert os.path.exists(os.path.join(tmp_path, "manifest.json"))
