"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (vocab sizes that do and don't divide the block
size, tiny/large field counts, degenerate d=1) and value regimes
(zero gradients, huge norms, zero counts). This is the core correctness
signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cowclip_clip, cowclip_clip_ref, fm2, fm2_bwd_ref, fm2_ref
from compile.kernels.cowclip import DEFAULT_V_BLOCK


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------- cowclip


@settings(deadline=None, max_examples=25)
@given(
    v=st.integers(1, 1400),
    d=st.sampled_from([1, 4, 10, 16]),
    seed=st.integers(0, 2**31 - 1),
    r=st.sampled_from([0.1, 1.0, 10.0]),
    zeta=st.sampled_from([0.0, 1e-5, 1e-3]),
)
def test_cowclip_matches_ref(v, d, seed, r, zeta):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    g = jax.random.normal(k1, (v, d))
    w = jax.random.normal(k2, (v, d)) * 0.01
    counts = jnp.floor(jax.random.uniform(k3, (v,)) * 4.0)
    got = cowclip_clip(g, w, counts, jnp.float32(r), jnp.float32(zeta))
    want = cowclip_clip_ref(g, w, counts, jnp.float32(r), jnp.float32(zeta))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("v_block", [32, 128, DEFAULT_V_BLOCK, 2048])
def test_cowclip_block_size_invariant(v_block):
    """Result must not depend on the VMEM tile size."""
    g = rand(0, (999, 10))
    w = rand(1, (999, 10), 0.01)
    counts = jnp.floor(jax.random.uniform(jax.random.PRNGKey(2), (999,)) * 3.0)
    got = cowclip_clip(g, w, counts, jnp.float32(1.0), jnp.float32(1e-4), v_block=v_block)
    want = cowclip_clip_ref(g, w, counts, jnp.float32(1.0), jnp.float32(1e-4))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)


def test_cowclip_zero_count_zeroes_nothing_extra():
    """cnt=0 rows have zero threshold; their (zero) gradients stay zero,
    and nonzero-count rows are untouched when under the threshold."""
    g = jnp.zeros((8, 4)).at[3].set(jnp.array([1e-6, 0, 0, 0]))
    w = jnp.full((8, 4), 0.1)
    counts = jnp.zeros((8,)).at[3].set(1.0)
    out = cowclip_clip(g, w, counts, jnp.float32(1.0), jnp.float32(1e-5))
    np.testing.assert_allclose(out, g, atol=1e-9)


def test_cowclip_clips_large_gradient_to_threshold():
    g = jnp.zeros((4, 4)).at[0].set(jnp.array([100.0, 0, 0, 0]))
    w = jnp.full((4, 4), 0.5)  # ||w_row|| = 1.0
    counts = jnp.ones((4,)) * 2.0
    out = cowclip_clip(g, w, counts, jnp.float32(1.0), jnp.float32(1e-5))
    # threshold = 2 * max(1.0, 1e-5) = 2.0
    np.testing.assert_allclose(
        float(jnp.linalg.norm(out[0])), 2.0, rtol=1e-5
    )


def test_cowclip_zeta_floor_engages_for_tiny_weights():
    g = jnp.ones((2, 4))  # norm 2.0
    w = jnp.zeros((2, 4))  # ||w|| = 0 -> threshold floor = zeta
    counts = jnp.ones((2,))
    zeta = jnp.float32(0.5)
    out = cowclip_clip(g, w, counts, jnp.float32(1.0), zeta)
    np.testing.assert_allclose(float(jnp.linalg.norm(out[0])), 0.5, rtol=1e-5)


def test_cowclip_direction_preserved():
    g = rand(5, (64, 10), 10.0)
    w = rand(6, (64, 10), 0.01)
    counts = jnp.ones((64,))
    out = cowclip_clip(g, w, counts, jnp.float32(1.0), jnp.float32(1e-4))
    # clipped gradient is a nonnegative scalar multiple of the input
    cross = jnp.sum(out * g, axis=-1)
    assert bool(jnp.all(cross >= 0))


# ---------------------------------------------------------------- fm2


@settings(deadline=None, max_examples=25)
@given(
    b=st.integers(1, 700),
    f=st.sampled_from([2, 5, 26]),
    d=st.sampled_from([1, 4, 10]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fm2_matches_ref(b, f, d, seed):
    v = jax.random.normal(jax.random.PRNGKey(seed), (b, f, d))
    np.testing.assert_allclose(fm2(v), fm2_ref(v), rtol=1e-4, atol=1e-4)


def test_fm2_known_value():
    # two fields, d=1: fm2 = v0*v1
    v = jnp.array([[[2.0], [3.0]]])
    np.testing.assert_allclose(fm2(v), [6.0], rtol=1e-6)


def test_fm2_pairwise_bruteforce():
    v = rand(7, (13, 6, 4))
    brute = jnp.zeros((13,))
    for i in range(6):
        for j in range(i + 1, 6):
            brute = brute + jnp.sum(v[:, i] * v[:, j], axis=-1)
    np.testing.assert_allclose(fm2(v), brute, rtol=1e-4, atol=1e-4)


@settings(deadline=None, max_examples=10)
@given(b=st.integers(1, 300), seed=st.integers(0, 2**31 - 1))
def test_fm2_grad_matches_ref_grad(b, seed):
    v = jax.random.normal(jax.random.PRNGKey(seed), (b, 8, 5))
    ct = jax.random.normal(jax.random.PRNGKey(seed + 1), (b,))
    g_pallas = jax.vjp(fm2, v)[1](ct)[0]
    np.testing.assert_allclose(g_pallas, fm2_bwd_ref(v, ct), rtol=1e-4, atol=1e-4)


def test_fm2_grad_through_jit():
    v = rand(9, (32, 26, 10))
    f = jax.jit(lambda v: jnp.sum(fm2(v) ** 2))
    g = jax.grad(f)(v)
    gr = jax.grad(lambda v: jnp.sum(fm2_ref(v) ** 2))(v)
    np.testing.assert_allclose(g, gr, rtol=1e-4, atol=1e-4)
