"""The clipping strategies ablated in Table 7 of the paper.

All variants share the signature
``clip(g, w, counts, hypers, schema) -> g'`` over the ``[V, d]``
embedding-gradient table; the selected variant is baked into each
``apply`` artifact at lowering time (gradient clipping is control-flow
free, so specialization beats a runtime switch).

Variants (Table 7 rows):
  * ``none``      — no clipping (the non-clipping scaling-rule baselines)
  * ``global``    — classic gradient-norm clipping over the whole table
  * ``field``     — per-field sub-table clipping, fixed threshold
  * ``column``    — per-id (row) clipping, fixed threshold
  * ``adafield``  — adaptive per-field: cnt_f * max(r*||w_f||, zeta)
  * ``cowclip``   — adaptive per-column (Alg. 1) via the Pallas kernel

Fixed thresholds read ``hypers[H_CLIP_T]``; the batch-size scaling of
that threshold (sqrt, per the paper's appendix) happens in the Rust
scaling engine before each step.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import cowclip_clip, cowclip_clip_ref
from .kernels.ref import EPS
from .schemas import Schema

# hypers vector layout (f32[8]); keep in sync with rust/src/runtime/hypers.rs
H_LR_DENSE = 0
H_LR_EMBED = 1
H_L2_EMBED = 2
H_CLIP_R = 3
H_CLIP_ZETA = 4
H_CLIP_T = 5
H_STEP = 6
H_RESERVED = 7
N_HYPERS = 8


def _clip_to(g: jnp.ndarray, norm: jnp.ndarray, thresh: jnp.ndarray) -> jnp.ndarray:
    """Rescale ``g`` so its norm is at most ``thresh`` (no-op below)."""
    return g * jnp.minimum(1.0, thresh / (norm + EPS))


def clip_none(g, w, counts, hypers, schema: Schema):
    return g


def clip_global(g, w, counts, hypers, schema: Schema):
    norm = jnp.sqrt(jnp.sum(g * g))
    return _clip_to(g, norm, hypers[H_CLIP_T])


def _field_slices(schema: Schema):
    offs = schema.offsets
    return [(o, o + v) for o, v in zip(offs, schema.vocab_sizes)]


def clip_field(g, w, counts, hypers, schema: Schema):
    out = []
    for lo, hi in _field_slices(schema):
        gf = g[lo:hi]
        norm = jnp.sqrt(jnp.sum(gf * gf))
        out.append(_clip_to(gf, norm, hypers[H_CLIP_T]))
    return jnp.concatenate(out, axis=0)


def clip_column(g, w, counts, hypers, schema: Schema):
    norm = jnp.sqrt(jnp.sum(g * g, axis=-1, keepdims=True))
    return _clip_to(g, norm, hypers[H_CLIP_T])


def clip_adafield(g, w, counts, hypers, schema: Schema):
    """Adaptive field-wise: threshold from the field sub-table's weight
    norm, scaled by the field's total batch occurrences (== batch size,
    since every sample carries exactly one id per field)."""
    r, zeta = hypers[H_CLIP_R], hypers[H_CLIP_ZETA]
    out = []
    for lo, hi in _field_slices(schema):
        gf, wf = g[lo:hi], w[lo:hi]
        cnt_f = jnp.sum(counts[lo:hi])
        norm = jnp.sqrt(jnp.sum(gf * gf))
        wnorm = jnp.sqrt(jnp.sum(wf * wf))
        thresh = cnt_f * jnp.maximum(r * wnorm, zeta)
        out.append(_clip_to(gf, norm, thresh))
    return jnp.concatenate(out, axis=0)


def clip_cowclip(g, w, counts, hypers, schema: Schema, use_pallas: bool = True,
                 v_block: int = 512):
    if use_pallas:
        return cowclip_clip(g, w, counts, hypers[H_CLIP_R], hypers[H_CLIP_ZETA],
                            v_block=v_block)
    return cowclip_clip_ref(g, w, counts, hypers[H_CLIP_R], hypers[H_CLIP_ZETA])


CLIP_MODES = {
    "none": clip_none,
    "global": clip_global,
    "field": clip_field,
    "column": clip_column,
    "adafield": clip_adafield,
    "cowclip": clip_cowclip,
}


def get_clip(mode: str):
    try:
        return CLIP_MODES[mode]
    except KeyError:
        raise KeyError(f"unknown clip mode {mode!r}; known: {sorted(CLIP_MODES)}")
