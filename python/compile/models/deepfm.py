"""DeepFM (Guo et al. 2018): FM wide stream + deep MLP stream.

  y_hat = w0 + sum_i w_i x_i  +  sum_{i<j} <v_i, v_j>  +  MLP(concat)

The second-order FM term runs through the Pallas ``fm2`` kernel
(``cfg.use_pallas=True``) or the jnp oracle, selected at trace time.
"""

from __future__ import annotations

import jax.numpy as jnp

from ..kernels import fm2, fm2_ref
from ..schemas import Schema
from . import common
from .common import ModelCfg, ParamReader, ParamSpec


def spec(schema: Schema, cfg: ModelCfg) -> ParamSpec:
    return (
        common.embed_spec(schema, cfg)
        + common.wide_spec(schema)
        + common.mlp_spec(common.dnn_input_dim(schema, cfg), cfg.hidden)
    )


def fwd(params, x_cat: jnp.ndarray, x_dense: jnp.ndarray, schema: Schema, cfg: ModelCfg) -> jnp.ndarray:
    r = ParamReader(params)
    embed_table = r.take()
    wide_table, wide_bias = r.take(), r.take()

    embeds = common.lookup_embeddings(embed_table, x_cat)      # [b, F, d]
    first_order = common.wide_logit(wide_table, wide_bias, x_cat)
    fm_fn = fm2 if cfg.use_pallas else fm2_ref
    second_order = fm_fn(embeds)                               # [b]
    deep = common.mlp_forward(r, common.deep_input(embeds, x_dense, schema), len(cfg.hidden))
    r.done()
    return first_order + second_order + deep
