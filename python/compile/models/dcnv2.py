"""DCN-v2 (Wang et al. 2021): full-rank cross layers.

  x_{l+1} = x0 ⊙ (W_l x_l + b_l) + x_l
"""

from __future__ import annotations

import jax.numpy as jnp

from ..schemas import Schema
from . import common
from .common import ModelCfg, ParamEntry, ParamReader, ParamSpec


def spec(schema: Schema, cfg: ModelCfg) -> ParamSpec:
    d0 = common.dnn_input_dim(schema, cfg)
    s = common.embed_spec(schema, cfg)
    for i in range(cfg.n_cross):
        s.append(ParamEntry(f"cross_W{i}", (d0, d0), "dense"))
        s.append(ParamEntry(f"cross_b{i}", (d0,), "dense"))
    s += common.mlp_hidden_spec(d0, cfg.hidden)
    s.append(ParamEntry("head_w", (d0 + cfg.hidden[-1], 1), "dense"))
    s.append(ParamEntry("head_b", (1,), "dense"))
    return s


def fwd(params, x_cat: jnp.ndarray, x_dense: jnp.ndarray, schema: Schema, cfg: ModelCfg) -> jnp.ndarray:
    r = ParamReader(params)
    embed_table = r.take()
    embeds = common.lookup_embeddings(embed_table, x_cat)
    x0 = common.deep_input(embeds, x_dense, schema)

    xl = x0
    for _ in range(cfg.n_cross):
        W, b = r.take(), r.take()
        xl = x0 * (xl @ W + b) + xl
    deep = common.mlp_hidden_forward(r, x0, len(cfg.hidden))
    head_w, head_b = r.take(), r.take()
    r.done()
    return (jnp.concatenate([xl, deep], axis=-1) @ head_w + head_b)[:, 0]
