"""Wide & Deep (Cheng et al. 2016): LR wide stream + deep MLP stream.

  y_hat = w0 + sum_i w_i x_i + MLP(concat)
"""

from __future__ import annotations

import jax.numpy as jnp

from ..schemas import Schema
from . import common
from .common import ModelCfg, ParamReader, ParamSpec


def spec(schema: Schema, cfg: ModelCfg) -> ParamSpec:
    return (
        common.embed_spec(schema, cfg)
        + common.wide_spec(schema)
        + common.mlp_spec(common.dnn_input_dim(schema, cfg), cfg.hidden)
    )


def fwd(params, x_cat: jnp.ndarray, x_dense: jnp.ndarray, schema: Schema, cfg: ModelCfg) -> jnp.ndarray:
    r = ParamReader(params)
    embed_table = r.take()
    wide_table, wide_bias = r.take(), r.take()

    embeds = common.lookup_embeddings(embed_table, x_cat)
    wide = common.wide_logit(wide_table, wide_bias, x_cat)
    deep = common.mlp_forward(r, common.deep_input(embeds, x_dense, schema), len(cfg.hidden))
    r.done()
    return wide + deep
