"""Shared building blocks for the CTR prediction models.

Parameters are **ordered tuples of arrays**, not pytrees-with-names: the
AOT interchange with Rust is positional, so every model publishes a
``ParamSpec`` — an ordered list of ``(name, shape, group)`` entries — that
is serialized into the artifact manifest. The Rust side constructs
literals in exactly that order and re-associates names/groups from the
manifest.

Groups drive the optimizer semantics from the paper:
  * ``embed``: the [V, d] id-embedding table — CowClip + L2 + eta_e
  * ``wide``:  the [V, 1] first-order table — L2 + eta_e, **no clipping**
               (the paper exempts the LR part, whose "embeddings" are
               1-dimensional biases)
  * ``dense``: MLP / cross weights — eta_dense, warmup, no L2, no clip
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax.numpy as jnp

from ..schemas import Schema


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    """Architecture hyperparameters (paper values scaled per DESIGN.md §4)."""

    embed_dim: int = 10
    hidden: Tuple[int, ...] = (128, 128, 128)
    n_cross: int = 3
    use_pallas: bool = True
    # Rows per CowClip-kernel grid step in the AOT build. The TPU-shaped
    # default in kernels/cowclip.py is 512 (VMEM-sized); the CPU artifacts
    # use a much larger block because interpret-mode pays ~1ms of
    # dynamic-slice machinery per grid step (measured in EXPERIMENTS.md
    # §Perf) and has no VMEM constraint.
    pallas_v_block: int = 8192


@dataclasses.dataclass(frozen=True)
class ParamEntry:
    name: str
    shape: Tuple[int, ...]
    group: str  # embed | wide | dense

    def to_json_dict(self) -> dict:
        return {"name": self.name, "shape": list(self.shape), "group": self.group}


ParamSpec = List[ParamEntry]


def embed_spec(schema: Schema, cfg: ModelCfg) -> ParamSpec:
    """The concatenated id-embedding table shared by every model."""
    return [ParamEntry("embed_table", (schema.total_vocab, cfg.embed_dim), "embed")]


def wide_spec(schema: Schema) -> ParamSpec:
    """First-order (LR/FM linear) weights: one scalar per id + a bias."""
    return [
        ParamEntry("wide_table", (schema.total_vocab, 1), "wide"),
        ParamEntry("wide_bias", (1,), "dense"),
    ]


def mlp_spec(in_dim: int, hidden: Sequence[int], prefix: str = "mlp") -> ParamSpec:
    """3-layer (by default) ReLU MLP + scalar output head."""
    spec: ParamSpec = []
    d = in_dim
    for i, h in enumerate(hidden):
        spec.append(ParamEntry(f"{prefix}_w{i}", (d, h), "dense"))
        spec.append(ParamEntry(f"{prefix}_b{i}", (h,), "dense"))
        d = h
    spec.append(ParamEntry(f"{prefix}_wout", (d, 1), "dense"))
    spec.append(ParamEntry(f"{prefix}_bout", (1,), "dense"))
    return spec


def mlp_hidden_spec(in_dim: int, hidden: Sequence[int], prefix: str = "mlp") -> ParamSpec:
    """MLP without the scalar head (DCN-style two-stream concat)."""
    spec: ParamSpec = []
    d = in_dim
    for i, h in enumerate(hidden):
        spec.append(ParamEntry(f"{prefix}_w{i}", (d, h), "dense"))
        spec.append(ParamEntry(f"{prefix}_b{i}", (h,), "dense"))
        d = h
    return spec


def dnn_input_dim(schema: Schema, cfg: ModelCfg) -> int:
    """Dim of the deep-stream input: flattened embeddings ++ dense fields."""
    return schema.n_cat * cfg.embed_dim + schema.n_dense


class ParamReader:
    """Sequential reader that pops arrays off the positional tuple in
    spec order, so each model's ``fwd`` stays declarative."""

    def __init__(self, params: Sequence[jnp.ndarray]):
        self._params = params
        self._i = 0

    def take(self) -> jnp.ndarray:
        p = self._params[self._i]
        self._i += 1
        return p

    def done(self) -> None:
        assert self._i == len(self._params), (
            f"consumed {self._i} of {len(self._params)} params"
        )


def lookup_embeddings(embed_table: jnp.ndarray, x_cat: jnp.ndarray) -> jnp.ndarray:
    """Gather per-field embedding vectors. ``x_cat`` holds *global* ids.

    Returns [b, F, d].
    """
    return embed_table[x_cat]


def wide_logit(wide_table: jnp.ndarray, wide_bias: jnp.ndarray, x_cat: jnp.ndarray) -> jnp.ndarray:
    """First-order logit: bias + sum of per-id scalar weights. -> [b]"""
    return jnp.sum(wide_table[x_cat][..., 0], axis=-1) + wide_bias[0]


def mlp_forward(reader: ParamReader, x: jnp.ndarray, n_hidden: int) -> jnp.ndarray:
    """ReLU MLP with scalar head. -> [b]"""
    h = x
    for _ in range(n_hidden):
        w, b = reader.take(), reader.take()
        h = jnp.maximum(h @ w + b, 0.0)
    w, b = reader.take(), reader.take()
    return (h @ w + b)[:, 0]


def mlp_hidden_forward(reader: ParamReader, x: jnp.ndarray, n_hidden: int) -> jnp.ndarray:
    """ReLU MLP without head. -> [b, hidden[-1]]"""
    h = x
    for _ in range(n_hidden):
        w, b = reader.take(), reader.take()
        h = jnp.maximum(h @ w + b, 0.0)
    return h


def deep_input(
    embeds: jnp.ndarray, x_dense: jnp.ndarray, schema: Schema
) -> jnp.ndarray:
    """Deep-stream input: flatten embeddings, append continuous fields."""
    b = embeds.shape[0]
    flat = embeds.reshape(b, -1)
    if schema.n_dense:
        flat = jnp.concatenate([flat, x_dense], axis=-1)
    return flat
