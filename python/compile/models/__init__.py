"""The four CTR prediction models evaluated by the paper.

Each model module exposes:
  ``spec(schema, cfg) -> ParamSpec``  — ordered positional parameter layout
  ``fwd(params, x_cat, x_dense, schema, cfg) -> logits [b]``
"""

from . import common, dcn, dcnv2, deepfm, wd
from .common import ModelCfg, ParamEntry, ParamSpec

MODELS = {
    "deepfm": deepfm,
    "wd": wd,
    "dcn": dcn,
    "dcnv2": dcnv2,
}


def get_model(name: str):
    try:
        return MODELS[name]
    except KeyError:
        raise KeyError(f"unknown model {name!r}; known: {sorted(MODELS)}")


__all__ = [
    "MODELS",
    "get_model",
    "ModelCfg",
    "ParamEntry",
    "ParamSpec",
    "common",
    "deepfm",
    "wd",
    "dcn",
    "dcnv2",
]
