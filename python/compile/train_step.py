"""Builders for the three AOT program kinds the Rust coordinator runs.

  * ``grad``  (per model, schema, microbatch): forward + backward + id
    occurrence counts. Pure w.r.t. hyperparameters so gradients can be
    tree-reduced across simulated workers and accumulated across
    microbatches to form an arbitrarily large effective batch.
  * ``apply`` (per model, schema, clip mode): clipping + L2 + Adam over
    the accumulated gradients. All optimizer hyperparameters arrive in a
    runtime ``hypers`` vector so the Rust scaling engine can sweep them
    without relowering.
  * ``fwd``   (per model, schema, eval batch): logits for evaluation.

Positional interfaces only — see ``models/common.py`` for the param-spec
contract and ``manifest.py`` for the JSON the Rust side consumes.
"""

from __future__ import annotations

from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from . import clipping, optim
from .clipping import H_L2_EMBED, H_LR_DENSE, H_LR_EMBED, H_STEP
from .models import ModelCfg, get_model
from .schemas import Schema


def bce_with_logits(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Numerically stable mean binary cross-entropy."""
    return jnp.mean(jnp.maximum(logits, 0.0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def build_grad_fn(model_name: str, schema: Schema, cfg: ModelCfg) -> Tuple[Callable, List[str]]:
    """(params..., x_cat, [x_dense], y) -> (grads..., counts, loss).

    Returns the function and the names of its non-param inputs.
    """
    model = get_model(model_name)
    n_params = len(model.spec(schema, cfg))
    has_dense = schema.n_dense > 0

    def fn(*args):
        params = args[:n_params]
        rest = args[n_params:]
        if has_dense:
            x_cat, x_dense, y = rest
        else:
            (x_cat, y) = rest
            x_dense = jnp.zeros((x_cat.shape[0], 0), jnp.float32)

        def loss_fn(ps):
            logits = model.fwd(ps, x_cat, x_dense, schema, cfg)
            return bce_with_logits(logits, y)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        counts = jnp.zeros((schema.total_vocab,), jnp.float32).at[x_cat.reshape(-1)].add(1.0)
        return (*grads, counts, loss)

    inputs = ["x_cat", "x_dense", "y"] if has_dense else ["x_cat", "y"]
    return fn, inputs


def build_apply_fn(model_name: str, schema: Schema, cfg: ModelCfg, clip_mode: str) -> Callable:
    """(params..., m..., v..., grads..., counts, hypers) -> (params'..., m'..., v'...)."""
    model = get_model(model_name)
    spec = model.spec(schema, cfg)
    n = len(spec)
    clip_fn = clipping.get_clip(clip_mode)

    def fn(*args):
        params = args[:n]
        ms = args[n : 2 * n]
        vs = args[2 * n : 3 * n]
        grads = args[3 * n : 4 * n]
        counts = args[4 * n]
        hypers = args[4 * n + 1]

        lr_dense = hypers[H_LR_DENSE]
        lr_embed = hypers[H_LR_EMBED]
        l2 = hypers[H_L2_EMBED]
        step = hypers[H_STEP]

        new_p, new_m, new_v = [], [], []
        for entry, w, m, v, g in zip(spec, params, ms, vs, grads):
            if entry.group == "embed":
                if clip_mode == "cowclip":
                    g = clip_fn(g, w, counts, hypers, schema,
                                use_pallas=cfg.use_pallas,
                                v_block=cfg.pallas_v_block)
                else:
                    g = clip_fn(g, w, counts, hypers, schema)
                g = g + l2 * w
                lr = lr_embed
            elif entry.group == "wide":
                # Paper exempts the 1-d LR "embeddings" from clipping but
                # keeps them under embedding LR + L2.
                g = g + l2 * w
                lr = lr_embed
            else:  # dense
                lr = lr_dense
            w2, m2, v2 = optim.adam_update(w, m, v, g, lr, step)
            new_p.append(w2)
            new_m.append(m2)
            new_v.append(v2)
        return (*new_p, *new_m, *new_v)

    return fn


def build_fwd_fn(model_name: str, schema: Schema, cfg: ModelCfg) -> Tuple[Callable, List[str]]:
    """(params..., x_cat, [x_dense]) -> (logits,)"""
    model = get_model(model_name)
    n_params = len(model.spec(schema, cfg))
    has_dense = schema.n_dense > 0

    def fn(*args):
        params = args[:n_params]
        rest = args[n_params:]
        if has_dense:
            x_cat, x_dense = rest
        else:
            (x_cat,) = rest
            x_dense = jnp.zeros((x_cat.shape[0], 0), jnp.float32)
        return (model.fwd(params, x_cat, x_dense, schema, cfg),)

    inputs = ["x_cat", "x_dense"] if has_dense else ["x_cat"]
    return fn, inputs
