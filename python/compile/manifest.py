"""Artifact manifest: the contract between the compile path and Rust.

``aot.py`` writes ``artifacts/manifest.json`` describing every lowered
HLO program: its positional input layout (names, dtypes, shapes), output
arity, parameter spec, the schema constants, and the optimizer/hypers
conventions. The Rust runtime (``rust/src/runtime/artifacts.rs``)
deserializes this file and refuses to run against a drifted layout.
"""

from __future__ import annotations

import dataclasses
import json
from typing import List, Optional

from .models import ModelCfg, get_model
from .schemas import SCHEMAS, Schema

MANIFEST_VERSION = 2

# Default program grid (see DESIGN.md §2): microbatch sizes the grad
# artifacts are specialized for, and the eval batch of fwd artifacts.
GRAD_MICROBATCHES = (64, 512)
EVAL_BATCH = 1024
ALL_MODELS = ("deepfm", "wd", "dcn", "dcnv2")
CORE_CLIPS = ("none", "cowclip")
ABLATION_CLIPS = ("global", "field", "column", "adafield")


@dataclasses.dataclass(frozen=True)
class ArtifactSpec:
    """One HLO program to lower."""

    kind: str                 # grad | apply | fwd
    model: str
    schema: str
    batch: Optional[int] = None   # grad/fwd only
    clip: Optional[str] = None    # apply only

    @property
    def artifact_id(self) -> str:
        if self.kind == "apply":
            return f"{self.schema}-{self.model}-apply-{self.clip}"
        return f"{self.schema}-{self.model}-{self.kind}-b{self.batch}"

    @property
    def filename(self) -> str:
        return f"{self.artifact_id}.hlo.txt"


def default_artifact_specs() -> List[ArtifactSpec]:
    """The full experiment grid (every table/figure in DESIGN.md §6)."""
    specs: List[ArtifactSpec] = []
    for schema in SCHEMAS:
        for model in ALL_MODELS:
            for mb in GRAD_MICROBATCHES:
                specs.append(ArtifactSpec("grad", model, schema, batch=mb))
            specs.append(ArtifactSpec("fwd", model, schema, batch=EVAL_BATCH))
            for clip in CORE_CLIPS:
                specs.append(ArtifactSpec("apply", model, schema, clip=clip))
    # Clipping-design ablation (Table 7) only needs DeepFM on Criteo.
    for clip in ABLATION_CLIPS:
        specs.append(ArtifactSpec("apply", "deepfm", "criteo_synth", clip=clip))
    return specs


def input_layout(spec: ArtifactSpec, schema: Schema, cfg: ModelCfg) -> List[dict]:
    """Positional input descriptors for one artifact."""
    model = get_model(spec.model)
    pspec = model.spec(schema, cfg)
    params = [
        {"name": e.name, "dtype": "f32", "shape": list(e.shape)} for e in pspec
    ]
    v = schema.total_vocab

    def data_inputs(batch: int, with_y: bool) -> List[dict]:
        ins = [{"name": "x_cat", "dtype": "i32", "shape": [batch, schema.n_cat]}]
        if schema.n_dense:
            ins.append({"name": "x_dense", "dtype": "f32", "shape": [batch, schema.n_dense]})
        if with_y:
            ins.append({"name": "y", "dtype": "f32", "shape": [batch]})
        return ins

    if spec.kind == "grad":
        return params + data_inputs(spec.batch, with_y=True)
    if spec.kind == "fwd":
        return params + data_inputs(spec.batch, with_y=False)
    if spec.kind == "apply":
        slots = []
        for tag in ("m", "v", "g"):
            slots += [
                {"name": f"{tag}.{e.name}", "dtype": "f32", "shape": list(e.shape)}
                for e in pspec
            ]
        return (
            params
            + slots
            + [
                {"name": "counts", "dtype": "f32", "shape": [v]},
                {"name": "hypers", "dtype": "f32", "shape": [8]},
            ]
        )
    raise ValueError(f"unknown kind {spec.kind}")


def output_arity(spec: ArtifactSpec, schema: Schema, cfg: ModelCfg) -> int:
    n = len(get_model(spec.model).spec(schema, cfg))
    if spec.kind == "grad":
        return n + 2  # grads..., counts, loss
    if spec.kind == "fwd":
        return 1
    if spec.kind == "apply":
        return 3 * n
    raise ValueError(spec.kind)


def build_manifest(specs: List[ArtifactSpec], cfg: ModelCfg) -> dict:
    artifacts = []
    for s in specs:
        schema = SCHEMAS[s.schema]
        artifacts.append(
            {
                "id": s.artifact_id,
                "kind": s.kind,
                "model": s.model,
                "schema": s.schema,
                "batch": s.batch,
                "clip": s.clip,
                "file": s.filename,
                "inputs": input_layout(s, schema, cfg),
                "n_outputs": output_arity(s, schema, cfg),
            }
        )
    param_specs = {}
    for schema_name, schema in SCHEMAS.items():
        for model_name in ALL_MODELS:
            key = f"{schema_name}-{model_name}"
            param_specs[key] = [
                e.to_json_dict() for e in get_model(model_name).spec(schema, cfg)
            ]
    return {
        "version": MANIFEST_VERSION,
        "model_cfg": {
            "embed_dim": cfg.embed_dim,
            "hidden": list(cfg.hidden),
            "n_cross": cfg.n_cross,
            "use_pallas": cfg.use_pallas,
        },
        "adam": {"beta1": 0.9, "beta2": 0.999, "eps": 1e-8},
        "hypers_layout": [
            "lr_dense", "lr_embed", "l2_embed", "clip_r",
            "clip_zeta", "clip_t", "step", "reserved",
        ],
        "schemas": {name: s.to_json_dict() for name, s in SCHEMAS.items()},
        "param_specs": param_specs,
        "artifacts": artifacts,
    }


def write_manifest(path: str, manifest: dict) -> None:
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1)
        f.write("\n")
