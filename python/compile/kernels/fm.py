"""Pallas kernels for the FM second-order interaction (DeepFM wide stream).

Forward computes ``sum_{i<j} <v_i, v_j>`` per sample via the classic
``0.5 * ((sum_f v)^2 - sum_f v^2)`` identity — two VPU reductions over the
field axis instead of an O(F^2) pairwise loop. The batch axis is tiled
with ``BlockSpec`` so each grid step streams a ``(B_BLK, F, d)`` slab
through VMEM.

``pallas_call`` has no automatic reverse-mode derivative, so the wrapper
installs a ``jax.custom_vjp`` whose backward pass is *also* a Pallas
kernel (the analytic gradient ``(sum_f' v) - v`` scaled by the upstream
cotangent). Both directions are validated against ``ref.py`` oracles by
the pytest/hypothesis suite.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_B_BLOCK = 256


def _fm2_fwd_kernel(v_ref, out_ref):
    v = v_ref[...]                      # [B_BLK, F, d]
    s = jnp.sum(v, axis=1)              # [B_BLK, d]
    sq = jnp.sum(v * v, axis=1)         # [B_BLK, d]
    out_ref[...] = 0.5 * jnp.sum(s * s - sq, axis=-1)


def _fm2_bwd_kernel(v_ref, ct_ref, out_ref):
    v = v_ref[...]                      # [B_BLK, F, d]
    ct = ct_ref[...]                    # [B_BLK]
    s = jnp.sum(v, axis=1, keepdims=True)
    out_ref[...] = (s - v) * ct[:, None, None]


def _pad_batch(x: jnp.ndarray, bb: int):
    pad = (-x.shape[0]) % bb
    if pad:
        widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        x = jnp.pad(x, widths)
    return x, pad


def _fm2_fwd_impl(v: jnp.ndarray, bb: int) -> jnp.ndarray:
    b, f, d = v.shape
    bb = min(bb, b) if b > 0 else bb
    vpad, pad = _pad_batch(v, bb)
    bp = b + pad
    out = pl.pallas_call(
        _fm2_fwd_kernel,
        grid=(bp // bb,),
        in_specs=[pl.BlockSpec((bb, f, d), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bp,), v.dtype),
        interpret=True,
    )(vpad)
    return out[:b] if pad else out


def _fm2_bwd_impl(v: jnp.ndarray, ct: jnp.ndarray, bb: int) -> jnp.ndarray:
    b, f, d = v.shape
    bb = min(bb, b) if b > 0 else bb
    vpad, pad = _pad_batch(v, bb)
    ctpad, _ = _pad_batch(ct, bb)
    bp = b + pad
    out = pl.pallas_call(
        _fm2_bwd_kernel,
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, f, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bb, f, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, f, d), v.dtype),
        interpret=True,
    )(vpad, ctpad)
    return out[:b] if pad else out


@jax.custom_vjp
def fm2(v: jnp.ndarray) -> jnp.ndarray:
    """FM second-order term per sample. ``v: [b, F, d] -> [b]``."""
    return _fm2_fwd_impl(v, DEFAULT_B_BLOCK)


def _fm2_vjp_fwd(v):
    return _fm2_fwd_impl(v, DEFAULT_B_BLOCK), v


def _fm2_vjp_bwd(v, ct):
    return (_fm2_bwd_impl(v, ct, DEFAULT_B_BLOCK),)


fm2.defvjp(_fm2_vjp_fwd, _fm2_vjp_bwd)
