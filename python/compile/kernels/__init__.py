"""Layer-1 Pallas kernels and their pure-jnp oracles."""

from .cowclip import cowclip_clip
from .fm import fm2
from .ref import cowclip_clip_ref, fm2_bwd_ref, fm2_ref

__all__ = ["cowclip_clip", "fm2", "cowclip_clip_ref", "fm2_ref", "fm2_bwd_ref"]
