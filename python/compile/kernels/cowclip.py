"""Pallas kernel for adaptive column-wise clipping (the CowClip hot-spot).

The clipping step of Algorithm 1 is a bandwidth-bound per-row reduction
over the ``[V, d]`` embedding-gradient table. On GPU the paper's
implementation maps one threadblock per embedding column; the TPU
adaptation (DESIGN.md §3) tiles the table into ``(V_BLK, d)`` VMEM blocks
streamed from HBM via ``BlockSpec`` — each block computes row-wise L2
norms on the VPU, derives the count-scaled adaptive threshold, and
rescales in place. With the default ``V_BLK = 512`` and d = 10 a block
holds ~20 KiB of input + output, leaving ample VMEM for double-buffering
the HBM stream.

``interpret=True`` is mandatory here: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers the kernel to plain HLO so
the AOT artifacts run anywhere. Real-TPU efficiency is estimated
analytically in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EPS

# Rows of the [V, d] table processed per grid step. Chosen so a block's
# in+out footprint (2 * V_BLK * d * 4B ≈ 40 KiB at d=10) double-buffers
# comfortably inside a ~16 MiB VMEM budget; see the block sweep in
# EXPERIMENTS.md §Perf.
DEFAULT_V_BLOCK = 512


def _cowclip_kernel(g_ref, w_ref, cnt_ref, rz_ref, out_ref):
    """One (V_BLK, d) tile: row norms -> adaptive threshold -> rescale."""
    g = g_ref[...]
    w = w_ref[...]
    cnt = cnt_ref[...]
    r = rz_ref[0]
    zeta = rz_ref[1]

    g_norm = jnp.sqrt(jnp.sum(g * g, axis=-1))
    w_norm = jnp.sqrt(jnp.sum(w * w, axis=-1))
    clip_t = cnt * jnp.maximum(r * w_norm, zeta)
    scale = jnp.minimum(1.0, clip_t / (g_norm + EPS))
    out_ref[...] = g * scale[:, None]


@functools.partial(jax.jit, static_argnames=("v_block",))
def cowclip_clip(
    g: jnp.ndarray,
    w: jnp.ndarray,
    counts: jnp.ndarray,
    r: jnp.ndarray,
    zeta: jnp.ndarray,
    *,
    v_block: int = DEFAULT_V_BLOCK,
) -> jnp.ndarray:
    """Clip each row of ``g`` to ``counts * max(r * ||w_row||, zeta)``.

    Semantics identical to :func:`compile.kernels.ref.cowclip_clip_ref`;
    the vocab dimension is padded up to a multiple of ``v_block`` (padded
    rows have zero gradient and zero count, so they are exact no-ops).

    Args:
      g:      [V, d] float32 gradient table.
      w:      [V, d] float32 weight table.
      counts: [V] float32 per-id batch occurrence counts.
      r, zeta: scalar float32 CowClip hyperparameters.
      v_block: rows per VMEM tile (power of two recommended).
    Returns:
      [V, d] clipped gradient table.
    """
    v, d = g.shape
    vb = min(v_block, v) if v > 0 else v_block
    pad = (-v) % vb
    if pad:
        g = jnp.pad(g, ((0, pad), (0, 0)))
        w = jnp.pad(w, ((0, pad), (0, 0)))
        counts = jnp.pad(counts, (0, pad))
    vp = v + pad
    rz = jnp.stack([r.astype(jnp.float32), zeta.astype(jnp.float32)])

    out = pl.pallas_call(
        _cowclip_kernel,
        grid=(vp // vb,),
        in_specs=[
            pl.BlockSpec((vb, d), lambda i: (i, 0)),
            pl.BlockSpec((vb, d), lambda i: (i, 0)),
            pl.BlockSpec((vb,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((vb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((vp, d), g.dtype),
        interpret=True,
    )(g, w, counts, rz)
    return out[:v] if pad else out
