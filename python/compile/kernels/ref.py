"""Pure-jnp oracles for the Pallas kernels.

These are the *correctness contracts*: every Pallas kernel in this package
must match its oracle to float32 tolerance on every shape/dtype the test
suite sweeps (see ``python/tests/test_kernels.py``). The oracles are also
what the JAX model uses when ``use_pallas=False`` is requested, so the AOT
artifacts can be built with or without the kernels for A/B benching.
"""

from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-12


def cowclip_clip_ref(
    g: jnp.ndarray,
    w: jnp.ndarray,
    counts: jnp.ndarray,
    r: jnp.ndarray,
    zeta: jnp.ndarray,
) -> jnp.ndarray:
    """Adaptive column-wise clipping (Alg. 1, lines 6-11) — oracle.

    One "column" of the paper's embedding matrix is one row of our
    ``[V, d]`` table (one id's embedding vector).

      clip_t[i] = counts[i] * max(r * ||w[i]||, zeta)
      g'[i]     = min(1, clip_t[i] / ||g[i]||) * g[i]

    Args:
      g:      [V, d] gradient of the embedding table (mean-of-batch).
      w:      [V, d] current embedding table.
      counts: [V]    number of occurrences of each id in the batch.
      r:      scalar CowClip ratio.
      zeta:   scalar lower bound on the pre-count threshold.
    """
    g_norm = jnp.sqrt(jnp.sum(g * g, axis=-1))
    w_norm = jnp.sqrt(jnp.sum(w * w, axis=-1))
    clip_t = counts * jnp.maximum(r * w_norm, zeta)
    scale = jnp.minimum(1.0, clip_t / (g_norm + EPS))
    return g * scale[:, None]


def fm2_ref(v: jnp.ndarray) -> jnp.ndarray:
    """FM second-order interaction term — oracle.

    sum_{i<j} <v_i, v_j> = 0.5 * sum_d ((sum_f v)^2 - sum_f v^2)

    Args:
      v: [b, F, d] per-field embedding vectors.
    Returns:
      [b] interaction logits.
    """
    s = jnp.sum(v, axis=1)          # [b, d]
    sq = jnp.sum(v * v, axis=1)     # [b, d]
    return 0.5 * jnp.sum(s * s - sq, axis=-1)


def fm2_bwd_ref(v: jnp.ndarray, ct: jnp.ndarray) -> jnp.ndarray:
    """VJP of :func:`fm2_ref`.

    d fm2 / d v[b, f, :] = (sum_f' v[b, f', :]) - v[b, f, :]

    Args:
      v:  [b, F, d] primal input.
      ct: [b] cotangent of the output.
    Returns:
      [b, F, d] cotangent of ``v``.
    """
    s = jnp.sum(v, axis=1, keepdims=True)  # [b, 1, d]
    return (s - v) * ct[:, None, None]
