"""Adam with loss-coupled L2 on the embedding tables (paper setting).

The paper trains every model with Adam and an L2 penalty *on the
embedding layers only* ("no L2-regularization is imposed on dense
weights"). The L2 gradient ``lambda * w`` is added analytically in the
apply step — equivalent to keeping the penalty in the loss, but it lets
the ``grad`` artifact stay regularization-free so the Rust coordinator
can sweep lambda without relowering.

Ordering w.r.t. clipping follows the paper's observation that embeddings
of absent ids keep shrinking under "continual application of
L2-regularization": the L2 term is added **after** clipping, so it is
never clipped away (a cnt=0 id has clip threshold 0, which would
otherwise zero its weight-decay pull).

The Rust reference optimizer (``rust/src/optim/adam.rs``) mirrors these
constants bit-for-bit; the parity test drives both on identical inputs.
"""

from __future__ import annotations

import jax.numpy as jnp

BETA1 = 0.9
BETA2 = 0.999
EPS = 1e-8


def adam_update(w, m, v, g, lr, step):
    """One Adam step. ``step`` is the 1-based step index (float32 scalar).

    Returns (w', m', v').
    """
    m2 = BETA1 * m + (1.0 - BETA1) * g
    v2 = BETA2 * v + (1.0 - BETA2) * (g * g)
    mhat = m2 / (1.0 - BETA1**step)
    vhat = v2 / (1.0 - BETA2**step)
    w2 = w - lr * mhat / (jnp.sqrt(vhat) + EPS)
    return w2, m2, v2
