"""AOT driver: lower every program in the manifest to HLO text.

Run once at build time (``make artifacts``); the Rust binary is fully
self-contained afterwards. HLO **text** is the interchange format — the
``xla`` crate's xla_extension 0.5.1 rejects jax>=0.5 serialized protos
(64-bit instruction ids), while the text parser reassigns ids cleanly
(see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--only SUBSTR] [--force]

Incremental: a content fingerprint of the compile package is stored in
``artifacts/.fingerprint``; unchanged sources skip relowering.
"""

from __future__ import annotations

import argparse
import hashlib
import os
import sys
import time
from typing import List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import manifest as mf
from . import train_step
from .models import ModelCfg
from .schemas import SCHEMAS

DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_fn(spec: mf.ArtifactSpec, cfg: ModelCfg):
    schema = SCHEMAS[spec.schema]
    if spec.kind == "grad":
        fn, _ = train_step.build_grad_fn(spec.model, schema, cfg)
    elif spec.kind == "fwd":
        fn, _ = train_step.build_fwd_fn(spec.model, schema, cfg)
    elif spec.kind == "apply":
        fn = train_step.build_apply_fn(spec.model, schema, cfg, spec.clip)
    else:
        raise ValueError(spec.kind)
    return fn


def lower_artifact(spec: mf.ArtifactSpec, cfg: ModelCfg) -> str:
    schema = SCHEMAS[spec.schema]
    fn = build_fn(spec, cfg)
    shapes = [
        jax.ShapeDtypeStruct(tuple(i["shape"]), DTYPES[i["dtype"]])
        for i in mf.input_layout(spec, schema, cfg)
    ]
    # keep_unused: an input unused by a variant (e.g. `counts` under
    # clip=none) must still appear in the program signature — the Rust
    # runtime feeds every manifest input positionally.
    return to_hlo_text(jax.jit(fn, keep_unused=True).lower(*shapes))


def source_fingerprint() -> str:
    """Hash of every .py under compile/ — drives incremental rebuilds."""
    root = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for dirpath, _, files in sorted(os.walk(root)):
        for name in sorted(files):
            if name.endswith(".py"):
                p = os.path.join(dirpath, name)
                h.update(p.encode())
                with open(p, "rb") as f:
                    h.update(f.read())
    return h.hexdigest()


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on artifact ids")
    ap.add_argument("--force", action="store_true", help="ignore fingerprint")
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower with the jnp oracles instead of Pallas kernels")
    args = ap.parse_args(argv)

    cfg = ModelCfg(use_pallas=not args.no_pallas)
    os.makedirs(args.out_dir, exist_ok=True)
    fp_path = os.path.join(args.out_dir, ".fingerprint")
    fingerprint = source_fingerprint() + ("-nopallas" if args.no_pallas else "")

    specs = mf.default_artifact_specs()
    if args.only:
        specs = [s for s in specs if args.only in s.artifact_id]

    if not args.force and not args.only and os.path.exists(fp_path):
        with open(fp_path) as f:
            if f.read().strip() == fingerprint and all(
                os.path.exists(os.path.join(args.out_dir, s.filename)) for s in specs
            ):
                print(f"artifacts up to date ({len(specs)} programs); skipping")
                return 0

    t0 = time.time()
    for i, spec in enumerate(specs):
        path = os.path.join(args.out_dir, spec.filename)
        t1 = time.time()
        text = lower_artifact(spec, cfg)
        with open(path, "w") as f:
            f.write(text)
        print(
            f"[{i + 1:3d}/{len(specs)}] {spec.artifact_id:<44s} "
            f"{len(text) / 1024:7.1f} KiB  {time.time() - t1:5.2f}s"
        )

    mf.write_manifest(os.path.join(args.out_dir, "manifest.json"),
                      mf.build_manifest(mf.default_artifact_specs(), cfg))
    if not args.only:
        with open(fp_path, "w") as f:
            f.write(fingerprint + "\n")
    print(f"lowered {len(specs)} programs in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
