"""Build-time compile path: JAX/Pallas authoring + AOT lowering to HLO text.

Nothing in this package is imported at runtime; the Rust coordinator only
consumes the HLO text + JSON manifests it emits under ``artifacts/``.
"""
