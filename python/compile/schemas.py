"""Dataset schemas shared between the compile path and the Rust runtime.

A schema describes the *shape* of a CTR dataset: how many continuous
(dense) fields it has, and the vocabulary size of every categorical field.
Categorical ids are stored **globally offset**: field ``j`` owns the id
range ``[offset[j], offset[j] + vocab[j])`` in one concatenated embedding
table, which is the standard single-table trick used by DLRM-style
systems.

The Rust side (``rust/src/data/schema.rs``) defines the same presets; the
AOT manifest (``artifacts/manifest.json``) embeds this schema so the Rust
test-suite cross-checks that the two never drift.

The presets are *synthetic, scaled-down* analogues of the paper's
datasets (see DESIGN.md §4): same field structure (13 dense + 26
categorical for Criteo, 24 categorical for Avazu), Zipf-distributed ids,
vocabularies shrunk ~1/8000 so that the batch-size scaling span of the
paper (1K → 128K) maps onto 64 → 8K while preserving the
``b * P(id in x)`` regime that drives the paper's analysis.
"""

from __future__ import annotations

import dataclasses
from typing import List


@dataclasses.dataclass(frozen=True)
class Schema:
    """Field layout of a CTR dataset."""

    name: str
    n_dense: int
    vocab_sizes: tuple  # vocab size per categorical field

    @property
    def n_cat(self) -> int:
        return len(self.vocab_sizes)

    @property
    def total_vocab(self) -> int:
        return int(sum(self.vocab_sizes))

    @property
    def offsets(self) -> List[int]:
        """Global id offset of each categorical field."""
        offs, acc = [], 0
        for v in self.vocab_sizes:
            offs.append(acc)
            acc += v
        return offs

    def to_json_dict(self) -> dict:
        return {
            "name": self.name,
            "n_dense": self.n_dense,
            "vocab_sizes": list(self.vocab_sizes),
            "total_vocab": self.total_vocab,
            "offsets": self.offsets,
        }


# Synthetic Criteo: 13 dense + 26 categorical fields. Vocab sizes span
# 4 decades, mimicking Figure 4 of the paper (a few huge long-tail fields,
# many mid-sized ones, and tiny near-binary fields like "gender").
CRITEO_SYNTH = Schema(
    name="criteo_synth",
    n_dense=13,
    vocab_sizes=(
        10000, 10000, 8000, 4000, 4000, 2000, 2000, 2000,
        1000, 1000, 1000, 500, 500, 500, 500, 300,
        300, 200, 100, 100, 50, 20, 10, 4, 3, 2,
    ),
)

# Synthetic Avazu: 24 categorical fields, no dense fields.
AVAZU_SYNTH = Schema(
    name="avazu_synth",
    n_dense=0,
    vocab_sizes=(
        8000, 8000, 4000, 2000, 2000, 1500, 1500, 1000,
        500, 500, 500, 300, 300, 300, 200, 200,
        100, 100, 50, 20, 10, 5, 3, 2,
    ),
)

SCHEMAS = {s.name: s for s in (CRITEO_SYNTH, AVAZU_SYNTH)}


def get_schema(name: str) -> Schema:
    try:
        return SCHEMAS[name]
    except KeyError:
        raise KeyError(f"unknown schema {name!r}; known: {sorted(SCHEMAS)}")
